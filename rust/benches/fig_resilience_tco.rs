//! FIG-RESILIENCE-TCO: what availability engineering costs, and what
//! skipping it costs more — {Llama 8B, 70B} x {H100-FP8, Gaudi 3-FP8}
//! x {colocated, disaggregated} x {zero-fault, N+1 redundancy,
//! unprotected} x an MTBF grid.
//!
//! Every cell serves the same seeded day of chat traffic on a
//! minimal fleet (one serving replica per pool). Three operating
//! postures price the same hardware three ways:
//!
//! * **zero-fault** — the accounting baseline: no faults, no spares.
//! * **redundant** — the serving replica (the prefill replica, on
//!   disaggregated cells) crashes a quarter into the day and fails
//!   over to an owned warm spare after `FAILOVER_S`; the spare's capex
//!   and rack share are billed (`k_spares = 1` through
//!   `InfraModel::cost_per_mtok_resilient`), crash victims recompute
//!   from scratch through the capped-backoff retry queue.
//! * **unprotected** — the same crash with no spare to fail over to:
//!   the replica stays down for the rest of the day, retries back off
//!   until they exhaust, and every undelivered token is gone. The
//!   $/Mtok denominator is *goodput* (`tokens_out - lost_tokens`), so
//!   the outage shows up as price, not as a footnote.
//!
//! The MTBF grid reruns the redundant posture under a seeded Poisson
//! crash/repair process at each MTBF — the frontier between hardware
//! reliability and the redundancy premium.
//!
//! Grounding assertions, every cell: all runs drain; token
//! conservation holds exactly (`tokens_out - lost_tokens` equals the
//! offered output tokens of every request that was not dropped); the
//! redundant posture drops nothing (its backoff budget outlasts the
//! failover window); and goodput-priced $/Mtok orders
//! zero-fault <= redundant <= unprotected.
//!
//! Run: `cargo bench --bench fig_resilience_tco`
//! (`SWEEP_FAST=1` shrinks the day for smoke tests.)

use std::collections::{BTreeMap, HashSet};

use fp8_tco::analysis::disagg::{DisaggPlan, PoolSpec};
use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::PrecisionMode;
use fp8_tco::coordinator::cluster::{disagg_sim_cluster, sharded_sim_cluster};
use fp8_tco::coordinator::{
    FaultDriver, FaultKind, FaultPlan, Metrics, Pool, RetryPolicy, SeqId,
};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{assumed_server_price_usd, DayUsage, InfraModel, RackConfig};
use fp8_tco::util::json::Json;
use fp8_tco::util::par::SweepGrid;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama::{by_name, LlamaConfig};
use fp8_tco::workload::trace::{Request, TraceConfig, TraceGenerator};

const SEED: u64 = 23;

/// Warm-spare promotion delay (s): detection + KV-cache-less restart.
const FAILOVER_S: f64 = 120.0;

/// Operator-grade retry budget: victims park up to ~211 s across 12
/// attempts, comfortably outlasting one failover window — so the
/// redundant posture drops nothing, while the unprotected one (down
/// for hours) still exhausts and sheds.
fn patient_retry() -> RetryPolicy {
    RetryPolicy { base_s: 0.5, cap_s: 30.0, max_attempts: 12 }
}

/// One measured posture of one cell.
struct Posture {
    drained: bool,
    usd_per_mtok: f64,
    wh_per_mtok: f64,
    goodput_tokens: u64,
    lost_tokens: u64,
    retries: u64,
    dropped: usize,
    down_s: f64,
    day_end_s: f64,
    /// Measured mean per-chip draw per pool (decode slot zero on
    /// colocated cells) — the zero-fault posture's pair becomes the
    /// shared rack-provisioning draw for every rerun of its cell.
    watts_mean: (f64, f64),
}

struct CellSetup {
    model: &'static LlamaConfig,
    dev: Device,
    shape: ParallelismPlan,
    disagg: bool,
    qps: f64,
}

fn precision(dev: Device) -> PrecisionMode {
    match dev {
        Device::H100 => PrecisionMode::fp8_dynamic(),
        _ => PrecisionMode::fp8_static(),
    }
}

/// Output tokens of every request that was *not* dropped — the exact
/// value `tokens_out - lost_tokens` must land on.
fn expected_goodput(reqs: &[Request], dropped: &[SeqId]) -> u64 {
    let dead: HashSet<SeqId> = dropped.iter().copied().collect();
    reqs.iter()
        .filter(|r| !dead.contains(&r.id))
        .map(|r| r.output_len as u64)
        .sum()
}

/// Run one posture of one cell and price it. `k_spares` replicas ride
/// along as owned-but-gated capacity; `provision` is the per-pool
/// per-chip draw the rack is packed for (the zero-fault posture's
/// measured means, shared by all postures so capex is
/// apples-to-apples; `None` means measure-and-use-own, which only the
/// zero-fault posture does).
#[allow(clippy::too_many_arguments)]
fn run_posture(
    infra: &InfraModel,
    cell: &CellSetup,
    reqs: &[Request],
    day_s: f64,
    plan: FaultPlan,
    k_spares: usize,
    provision: Option<(f64, f64)>,
) -> Posture {
    let chips = cell.shape.chips_per_instance();
    let price = assumed_server_price_usd(cell.dev);
    let prec = precision(cell.dev);
    let faults = FaultDriver::new(plan, patient_retry());
    if cell.disagg {
        let dplan = DisaggPlan::new(
            PoolSpec::new(cell.dev, prec, cell.shape),
            PoolSpec::new(cell.dev, prec, cell.shape),
        );
        let mut c = disagg_sim_cluster(cell.model, &dplan)
            .unwrap_or_else(|e| panic!("cell must fit: {e}"))
            .with_faults(faults);
        let drained = c.run(reqs.iter().cloned());
        let day_end = day_s.max(c.makespan());
        c.prefill.close_ledgers(day_end);
        c.decode.close_ledgers(day_end);
        let (pm, dm) = c.pool_metrics();
        let mm = c.merged_metrics();
        assert_eq!(
            mm.tokens_out - mm.lost_tokens,
            expected_goodput(reqs, &c.faults.dropped),
            "token conservation across faults"
        );
        // Each pool is one server-equivalent sharing the merged
        // goodput; the spare (when owned) shadows the prefill replica
        // — the pool the engineered crash targets.
        let pool_usage = |m: &Metrics| {
            let mut u = DayUsage::from_fleet(m, chips, day_end);
            u.tokens_out = mm.tokens_out;
            u.lost_tokens = mm.lost_tokens;
            u
        };
        let up = pool_usage(&pm);
        let ud = pool_usage(&dm);
        let (w_p, w_d) = provision.unwrap_or_else(|| (pm.watts_mean(), dm.watts_mean()));
        let usd = infra.cost_per_mtok_resilient(price, chips, 1, k_spares, w_p, &up)
            + infra.cost_per_mtok_resilient(price, chips, 1, 0, w_d, &ud);
        let goodput = up.goodput_tokens();
        let wh = (infra.wh_per_mtok_diurnal(chips, &up)
            + infra.wh_per_mtok_diurnal(chips, &ud))
            * up.tokens_out as f64
            / goodput as f64;
        Posture {
            drained,
            usd_per_mtok: usd,
            wh_per_mtok: wh,
            goodput_tokens: goodput,
            lost_tokens: mm.lost_tokens,
            retries: mm.retries,
            dropped: c.faults.dropped.len(),
            down_s: mm.down_s,
            day_end_s: day_end,
            watts_mean: (pm.watts_mean(), dm.watts_mean()),
        }
    } else {
        let mut c = sharded_sim_cluster(cell.model, cell.dev, prec, cell.shape)
            .unwrap_or_else(|e| panic!("cell must fit: {e}"))
            .with_faults(faults);
        let drained = c.run(reqs.iter().cloned());
        let day_end = day_s.max(c.makespan());
        c.router.close_ledgers(day_end);
        let m = c.merged_metrics();
        assert_eq!(
            m.tokens_out - m.lost_tokens,
            expected_goodput(reqs, &c.faults.dropped),
            "token conservation across faults"
        );
        let u = DayUsage::from_fleet(&m, chips, day_end);
        let w = provision.map_or_else(|| m.watts_mean(), |(w, _)| w);
        let usd = infra.cost_per_mtok_resilient(price, chips, 1, k_spares, w, &u);
        let goodput = u.goodput_tokens();
        let wh =
            infra.wh_per_mtok_diurnal(chips, &u) * u.tokens_out as f64 / goodput as f64;
        Posture {
            drained,
            usd_per_mtok: usd,
            wh_per_mtok: wh,
            goodput_tokens: goodput,
            lost_tokens: m.lost_tokens,
            retries: m.retries,
            dropped: c.faults.dropped.len(),
            down_s: m.down_s,
            day_end_s: day_end,
            watts_mean: (m.watts_mean(), 0.0),
        }
    }
}

fn main() {
    let fast = std::env::var("SWEEP_FAST").ok().as_deref() == Some("1");
    let day_s = if fast { 600.0 } else { 3600.0 };
    let crash_at = 0.25 * day_s;
    // Hardware MTBF grid for the Poisson frontier: flaky to merely
    // unreliable, scaled so even the fast day expects a crash or two.
    let mtbfs: &[f64] = if fast { &[300.0] } else { &[900.0, 1800.0] };
    let infra = InfraModel::new(RackConfig::a100_era());
    let m8 = by_name("llama-8b").unwrap();
    let m70 = by_name("llama-70b").unwrap();
    // (model, device, shape, single-replica QPS): shapes from the
    // diurnal bench, loads comfortably inside one replica's capacity.
    let mut cells: Vec<CellSetup> = Vec::new();
    for disagg in [false, true] {
        cells.push(CellSetup { model: m8, dev: Device::H100, shape: ParallelismPlan::single(), disagg, qps: 2.0 });
        cells.push(CellSetup { model: m8, dev: Device::Gaudi3, shape: ParallelismPlan::single(), disagg, qps: 2.0 });
        cells.push(CellSetup { model: m70, dev: Device::H100, shape: ParallelismPlan::tp(2), disagg, qps: 0.4 });
        cells.push(CellSetup { model: m70, dev: Device::Gaudi3, shape: ParallelismPlan::single(), disagg, qps: 0.4 });
    }

    // The crash targets the pool whose loss actually severs service:
    // the lone primary replica (colocated) or the lone prefill replica
    // (disaggregated — delivered decode legs keep streaming, new work
    // cannot start).
    let crash_pool = |disagg: bool| if disagg { Pool::Prefill } else { Pool::Primary };

    struct CellOut {
        label: String,
        zero: Posture,
        redundant: Posture,
        unprotected: Posture,
        by_mtbf: Vec<(f64, Posture)>,
    }

    let measured: Vec<CellOut> = SweepGrid::new((0..cells.len()).collect::<Vec<_>>())
        .run(|_, ci| {
            let cell = &cells[ci];
            let mut gen = TraceGenerator::new(TraceConfig::chat(cell.qps), SEED);
            let mut reqs: Vec<Request> = Vec::new();
            loop {
                let r = gen.next_request();
                if r.arrival > day_s {
                    break;
                }
                reqs.push(r);
            }
            let pool = crash_pool(cell.disagg);
            let zero =
                run_posture(&infra, cell, &reqs, day_s, FaultPlan::new(), 0, None);
            // All postures pack the rack for the zero-fault draw; the
            // reruns share the trace, so capex deltas are pure
            // redundancy, never provisioning drift.
            let provision = Some(zero.watts_mean);
            let redundant = run_posture(
                &infra,
                cell,
                &reqs,
                day_s,
                FaultPlan::new().crash_repair(pool, 0, crash_at, FAILOVER_S),
                1,
                provision,
            );
            let unprotected = run_posture(
                &infra,
                cell,
                &reqs,
                day_s,
                FaultPlan::new().with(crash_at, FaultKind::Crash { pool, replica: 0 }),
                0,
                provision,
            );
            let by_mtbf: Vec<(f64, Posture)> = mtbfs
                .iter()
                .map(|&mtbf| {
                    let plan = FaultPlan::new().poisson_crashes(
                        SEED ^ ci as u64,
                        pool,
                        1,
                        mtbf,
                        FAILOVER_S,
                        day_s,
                    );
                    (mtbf, run_posture(&infra, cell, &reqs, day_s, plan, 1, provision))
                })
                .collect();
            let label = format!(
                "{} {} {}",
                cell.model.name,
                cell.dev.name(),
                if cell.disagg { "disagg" } else { "colocated" }
            );
            CellOut { label, zero, redundant, unprotected, by_mtbf }
        });

    for c in &measured {
        assert!(
            c.zero.drained && c.redundant.drained && c.unprotected.drained,
            "{}: every posture must drain",
            c.label
        );
        assert_eq!(c.zero.lost_tokens, 0, "{}: fault-free day lost tokens", c.label);
        assert_eq!(c.zero.dropped, 0, "{}: fault-free day dropped requests", c.label);
        assert_eq!(
            c.redundant.dropped, 0,
            "{}: failover outlasts the backoff budget, nothing drops",
            c.label
        );
        assert!(c.redundant.retries >= 1, "{}: the crash must retry work", c.label);
        assert!(
            c.unprotected.goodput_tokens < c.zero.goodput_tokens,
            "{}: a dead unprotected replica must shed goodput",
            c.label
        );
        assert!(
            c.zero.usd_per_mtok <= c.redundant.usd_per_mtok * (1.0 + 1e-9),
            "{}: zero-fault {} must not exceed redundant {}",
            c.label,
            c.zero.usd_per_mtok,
            c.redundant.usd_per_mtok
        );
        assert!(
            c.redundant.usd_per_mtok <= c.unprotected.usd_per_mtok * (1.0 + 1e-9),
            "{}: redundant {} must not exceed unprotected {}",
            c.label,
            c.redundant.usd_per_mtok,
            c.unprotected.usd_per_mtok
        );
        for (mtbf, p) in &c.by_mtbf {
            assert!(p.drained, "{} mtbf {mtbf}: must drain", c.label);
            assert!(
                c.zero.usd_per_mtok <= p.usd_per_mtok * (1.0 + 1e-9),
                "{} mtbf {mtbf}: faults + a spare cannot beat the clean day",
                c.label
            );
        }
    }

    let mut t = Table::new(
        "Fig. RESILIENCE-TCO — goodput-priced $/Mtok: zero-fault vs N+1 warm-spare \
         failover vs unprotected crash, plus a Poisson MTBF grid",
        &[
            "cell",
            "posture",
            "goodput Mtok",
            "lost tok",
            "retries",
            "dropped",
            "down s",
            "day end s",
            "Wh/Mtok",
            "$/Mtok",
        ],
    );
    let mut records: Vec<Json> = Vec::new();
    let emit = |t: &mut Table, label: &str, posture: &str, mtbf: Option<f64>, p: &Posture,
                records: &mut Vec<Json>| {
        let mut rec = BTreeMap::new();
        rec.insert("cell".into(), Json::Str(label.into()));
        rec.insert("posture".into(), Json::Str(posture.into()));
        if let Some(m) = mtbf {
            rec.insert("mtbf_s".into(), Json::Num(m));
        }
        rec.insert("feasible".into(), Json::Bool(p.drained));
        rec.insert("goodput_tokens".into(), Json::Num(p.goodput_tokens as f64));
        rec.insert("lost_tokens".into(), Json::Num(p.lost_tokens as f64));
        rec.insert("retries".into(), Json::Num(p.retries as f64));
        rec.insert("dropped".into(), Json::Num(p.dropped as f64));
        rec.insert("down_s".into(), Json::Num(p.down_s));
        rec.insert("day_end_s".into(), Json::Num(p.day_end_s));
        rec.insert("wh_per_mtok".into(), Json::Num(p.wh_per_mtok));
        rec.insert("usd_per_mtok".into(), Json::Num(p.usd_per_mtok));
        records.push(Json::Obj(rec));
        t.row(vec![
            label.into(),
            match mtbf {
                Some(m) => format!("{posture} mtbf={m:.0}s"),
                None => posture.into(),
            },
            f(p.goodput_tokens as f64 / 1e6, 3),
            format!("{}", p.lost_tokens),
            format!("{}", p.retries),
            format!("{}", p.dropped),
            f(p.down_s, 0),
            f(p.day_end_s, 0),
            f(p.wh_per_mtok, 1),
            f(p.usd_per_mtok, 3),
        ]);
    };
    for c in &measured {
        emit(&mut t, &c.label, "zero-fault", None, &c.zero, &mut records);
        emit(&mut t, &c.label, "redundant", None, &c.redundant, &mut records);
        emit(&mut t, &c.label, "unprotected", None, &c.unprotected, &mut records);
        for (mtbf, p) in &c.by_mtbf {
            emit(&mut t, &c.label, "poisson", Some(*mtbf), p, &mut records);
        }
    }
    t.print();

    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/BENCH_resilience_tco.json");
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("resilience_tco".into()));
    root.insert("fast".into(), Json::Bool(fast));
    root.insert("day_s".into(), Json::Num(day_s));
    root.insert("failover_s".into(), Json::Num(FAILOVER_S));
    root.insert("crash_at_s".into(), Json::Num(crash_at));
    root.insert(
        "mtbf_grid_s".into(),
        Json::Arr(mtbfs.iter().map(|&m| Json::Num(m)).collect()),
    );
    root.insert("cells".into(), Json::Arr(records));
    match std::fs::write(&path, format!("{}\n", Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    println!(
        "(every posture owns the same serving hardware; the redundant rows add one\n \
         warm spare's capex + rack share, the unprotected rows pay with goodput —\n \
         dropped requests and a day that ends when the backlog does, not on time)"
    );
}
