//! Disaggregated prefill/decode serving walkthrough (DESIGN.md §7).
//!
//! Part 1 prices three deployments of the same 4-chip budget serving
//! the same chat workload at the interactive SLO:
//!
//! * colocated — every engine interleaves prefill and decode (the
//!   PR-1/PR-2 serving shape);
//! * disaggregated, homogeneous — an H100 prefill pool feeding an
//!   H100 decode pool over the scale-out fabric, pool sizes balanced
//!   by `analysis::disagg::auto_size`;
//! * disaggregated, mixed-vendor — H100 prefill + Gaudi 2 decode, the
//!   paper's per-phase result turned into a deployable TCO lever;
//! * the `-stream` variants of both — KV migrated as 8 chunks with
//!   first-chunk delivery (TTFT overlap) and decode-pool admission
//!   control (DESIGN.md §8);
//! * PhaseAffinity — 2 colocated H100 engines beside a 1+1 disagg
//!   pair, long prompts routed to the pair, short ones colocated.
//!
//! Part 2 sweeps the KV-migration link (bandwidth scaling, added
//! latency and chunk count) at a fixed load to show where the fabric
//! starts eating the TTFT budget — and how much chunked streaming
//! claws back.
//!
//! Run: `cargo run --release --example disagg_sweep`
//! (`SWEEP_FAST=1` shrinks the SLO search for smoke tests.)

use fp8_tco::analysis::disagg::{auto_size, DisaggPlan, PhaseAffinityPlan, PoolSpec};
use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::PrecisionMode;
use fp8_tco::coordinator::cluster::{
    disagg_sim_cluster, max_sustainable_qps, phase_affinity_sim_cluster, replay_affinity_point,
    replay_disagg_point, sharded_sim_cluster, SloSpec, SweepConfig,
};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{assumed_server_price_usd, InfraModel, RackConfig};
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama::by_name;
use fp8_tco::workload::trace::{TraceConfig, TraceGenerator};

fn main() {
    let fast = std::env::var("SWEEP_FAST").ok().as_deref() == Some("1");
    let slo = SloSpec::interactive();
    let sweep = if fast {
        SweepConfig { iters: 2, n_requests: 30, seed: 7, ..SweepConfig::new(0.25, 8.0) }
    } else {
        SweepConfig { iters: 4, n_requests: 100, seed: 7, ..SweepConfig::new(0.25, 24.0) }
    };
    let infra = InfraModel::new(RackConfig::a100_era());
    let model = by_name("llama-8b").unwrap();
    // Chat-mix medians drive the pool balance.
    let (p_med, o_med) = (245usize, 148usize);
    let h100 = PoolSpec::new(
        Device::H100,
        PrecisionMode::fp8_dynamic(),
        ParallelismPlan::single(),
    );
    let gaudi2 = PoolSpec::new(
        Device::Gaudi2,
        PrecisionMode::fp8_static(),
        ParallelismPlan::single(),
    );
    let homog = auto_size(model, h100, h100, p_med, o_med, 4);
    let mixed = auto_size(model, h100, gaudi2, p_med, o_med, 4);

    println!(
        "Disaggregated prefill/decode serving — llama-8b, chat traffic, \
         interactive SLO (TTFT p95 <= {:.1} s, TPOT p95 <= {:.0} ms).\n",
        slo.ttft_p95_s,
        slo.tpot_p95_s * 1e3
    );

    let mut t = Table::new(
        "Colocated vs disaggregated vs mixed-vendor (4-chip budget)",
        &[
            "mode",
            "pools",
            "QPS @SLO",
            "tok/s",
            "TTFT p95 ms",
            "TPOT p95 ms",
            "migrations",
            "$/Mtok @SLO",
        ],
    );

    // Colocated baseline: 4 fused H100 engines.
    let colo_plan = ParallelismPlan::single().with_replicas(4);
    // PhaseAffinity: 2 colocated H100 engines + the 1+1 mixed-vendor
    // pair, prompts >= 2x the chat median routed to the pair.
    let affinity = PhaseAffinityPlan::new(
        PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single().with_replicas(2),
        ),
        DisaggPlan::new(
            PoolSpec::new(
                Device::H100,
                PrecisionMode::fp8_dynamic(),
                ParallelismPlan::single(),
            ),
            PoolSpec::new(
                Device::Gaudi2,
                PrecisionMode::fp8_static(),
                ParallelismPlan::single(),
            ),
        ),
        2 * p_med,
    );

    // All six deployment cells are independent SLO searches on fresh
    // clusters: evaluate concurrently (PAR=0 forces serial) and render
    // in cell order — the printed table is byte-identical either way.
    enum Pt {
        Colo,
        Variant(&'static str, DisaggPlan, usize, bool),
        Affinity,
    }
    let pts = vec![
        Pt::Colo,
        Pt::Variant("disagg", homog, 1, false),
        Pt::Variant("disagg-stream", homog, 8, true),
        Pt::Variant("mixed", mixed, 1, false),
        Pt::Variant("mixed-stream", mixed, 8, true),
        Pt::Affinity,
    ];
    let rows: Vec<Option<Vec<String>>> = fp8_tco::util::par::SweepGrid::new(pts).run(|_, pt| {
        match pt {
            Pt::Colo => {
                let colo = max_sustainable_qps(
                    &|| {
                        sharded_sim_cluster(
                            model,
                            Device::H100,
                            PrecisionMode::fp8_dynamic(),
                            colo_plan,
                        )
                        .expect("8B fits one H100")
                    },
                    &TraceConfig::chat,
                    &slo,
                    &sweep,
                );
                colo.best.map(|p| {
                    let cost = infra.cost_per_mtok_sharded(
                        assumed_server_price_usd(Device::H100),
                        colo_plan.total_chips(),
                        p.watts_mean,
                        p.tokens_per_sec,
                    );
                    vec![
                        "colocated".into(),
                        format!("H100 {colo_plan}"),
                        f(p.qps, 2),
                        f(p.tokens_per_sec, 0),
                        f(p.ttft_p95 * 1e3, 1),
                        f(p.tpot_p95 * 1e3, 2),
                        "0".into(),
                        f(cost, 3),
                    ]
                })
            }
            Pt::Variant(mode, plan, chunks, admission) => {
                let out = max_sustainable_qps(
                    &|| {
                        disagg_sim_cluster(model, &plan)
                            .expect("pools must be feasible")
                            .with_streaming(chunks, admission)
                    },
                    &TraceConfig::chat,
                    &slo,
                    &sweep,
                );
                Some(match out.best {
                    Some(p) => {
                        // Replay the operating point to split watts per
                        // pool (heterogeneous pools price at their own
                        // draw).
                        let (pm, dm, merged) = replay_disagg_point(
                            model,
                            &plan,
                            chunks,
                            admission,
                            TraceConfig::chat(p.qps),
                            sweep.n_requests,
                            sweep.seed,
                        )
                        .expect("plan was feasible for the probe");
                        let cost = infra.cost_per_mtok_disagg_plan(
                            &plan,
                            pm.watts_mean(),
                            dm.watts_mean(),
                            p.tokens_per_sec,
                        );
                        vec![
                            mode.into(),
                            plan.describe(),
                            f(p.qps, 2),
                            f(p.tokens_per_sec, 0),
                            f(p.ttft_p95 * 1e3, 1),
                            f(p.tpot_p95 * 1e3, 2),
                            format!("{}", merged.migrations),
                            f(cost, 3),
                        ]
                    }
                    None => vec![
                        mode.into(),
                        plan.describe(),
                        format!("< {}", sweep.qps_lo),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ],
                })
            }
            Pt::Affinity => {
                let out = max_sustainable_qps(
                    &|| {
                        phase_affinity_sim_cluster(model, &affinity)
                            .expect("pools must be feasible")
                            .with_streaming(8, true)
                    },
                    &TraceConfig::chat,
                    &slo,
                    &sweep,
                );
                out.best.map(|p| {
                    let (cm, pm, dm, merged) = replay_affinity_point(
                        model,
                        &affinity,
                        8,
                        true,
                        TraceConfig::chat(p.qps),
                        sweep.n_requests,
                        sweep.seed,
                    )
                    .expect("plan was feasible for the probe");
                    let cost = infra.cost_per_mtok_phase_affinity_plan(
                        &affinity,
                        cm.watts_mean(),
                        pm.watts_mean(),
                        dm.watts_mean(),
                        p.tokens_per_sec,
                    );
                    vec![
                        "affinity".into(),
                        affinity.describe(),
                        f(p.qps, 2),
                        f(p.tokens_per_sec, 0),
                        f(p.ttft_p95 * 1e3, 1),
                        f(p.tpot_p95 * 1e3, 2),
                        format!("{}", merged.migrations),
                        f(cost, 3),
                    ]
                })
            }
        }
    });
    for row in rows.into_iter().flatten() {
        t.row(row);
    }
    t.print();

    // Part 2: link sensitivity at a fixed, comfortably feasible load.
    let qps = 2.0;
    let n = if fast { 40 } else { 120 };
    println!(
        "\nKV-link sensitivity — mixed-vendor plan at {qps} QPS ({n} requests):\n\
         the closed form bytes/bw + lat is charged per migrated context."
    );
    let mut t2 = Table::new(
        "TTFT vs the migration link (chunked streaming claws back the fabric)",
        &["link", "chunks", "TTFT p50 ms", "TTFT p95 ms", "KV GB moved"],
    );
    let base = mixed.kv_link();
    let variants: [(String, f64, f64, usize); 6] = [
        ("infinite".into(), f64::INFINITY, 0.0, 1),
        (format!("{:.0} GB/s (datasheet)", base.bw / 1e9), base.bw, base.lat_s, 1),
        (format!("{:.0} GB/s (datasheet)", base.bw / 1e9), base.bw, base.lat_s, 8),
        ("1/10 bandwidth".into(), base.bw / 10.0, base.lat_s, 1),
        ("1/10 bandwidth".into(), base.bw / 10.0, base.lat_s, 8),
        ("+10 ms latency".into(), base.bw, base.lat_s + 0.010, 1),
    ];
    // Fixed-load sensitivity runs are independent too — same parallel
    // evaluation, same rendered bytes.
    let rows2: Vec<Vec<String>> = fp8_tco::util::par::SweepGrid::new(variants.to_vec()).run(
        |_, (name, bw, lat_s, chunks)| {
            let mut c = disagg_sim_cluster(model, &mixed)
                .unwrap()
                .with_streaming(chunks, false);
            c.link.bw = bw;
            c.link.lat_s = lat_s;
            let gen = TraceGenerator::new(TraceConfig::chat(qps), 13);
            let drained = c.run(gen.stream(n));
            let m = c.merged_metrics();
            assert!(drained, "sensitivity run must drain");
            vec![
                name,
                format!("{chunks}"),
                f(m.ttft.pct(50.0) * 1e3, 1),
                f(m.ttft.pct(95.0) * 1e3, 1),
                f(m.kv_bytes_migrated / 1e9, 2),
            ]
        },
    );
    for row in rows2 {
        t2.row(row);
    }
    t2.print();
    println!(
        "\n(The mixed-vendor row is the paper's §2.2/Splitwise argument priced\n \
         end-to-end: prefill on the compute-rich H100, decode on the cheaper,\n \
         cooler Gaudi 2 — with the KV migration charged against the fabric.)"
    );
}
