//! Phase-aware decode analysis (paper §5): where does a decode step's
//! time go, per device / precision / batch / sequence length, and when
//! does each §5.2 bottleneck (thin-GEMM feed, KV bandwidth, softmax)
//! take over.
//!
//! Run: `cargo run --release --example decode_analysis [model]`

use fp8_tco::analysis::perfmodel::{decode_step, PrecisionMode, StepConfig};
use fp8_tco::analysis::roofline::saturation_ci;
use fp8_tco::hwsim::spec::{DType, Device};
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args
        .first()
        .and_then(|a| llama::by_name(a))
        .unwrap_or_else(|| llama::by_name("llama-8b").unwrap());

    println!(
        "model {} | A={} | params {:.1}B | CI to saturate Gaudi2 FP8: {:.0}\n",
        model.name,
        model.a_const(),
        model.param_count() / 1e9,
        saturation_ci(Device::Gaudi2.spec(), DType::Fp8),
    );

    // ---- time breakdown across sequence lengths ------------------
    let mut t = Table::new(
        "decode step breakdown, b=64 (ms)",
        &["device", "prec", "s", "total", "linears", "kv", "softmax", "head",
          "tok/s", "CI"],
    );
    for dev in [Device::Gaudi2, Device::H100] {
        for prec in [PrecisionMode::Bf16, PrecisionMode::fp8_static()] {
            for s in [256usize, 1024, 4096, 16384] {
                let bd = decode_step(model, &StepConfig::new(dev, prec), 64, s);
                let w_bytes = match prec {
                    PrecisionMode::Bf16 => 2.0,
                    _ => 1.0,
                };
                t.row(vec![
                    dev.name().into(),
                    prec.name().into(),
                    s.to_string(),
                    f(bd.seconds * 1e3, 2),
                    f(bd.t_linears_s * 1e3, 2),
                    f(bd.t_attention_kv_s * 1e3, 2),
                    f(bd.t_softmax_s * 1e3, 3),
                    f(bd.t_lm_head_s * 1e3, 2),
                    f(64.0 / bd.seconds, 0),
                    f(model.decode_ci(64, s, w_bytes, 2.0), 1),
                ]);
            }
        }
    }
    t.print();

    // ---- batch scaling -------------------------------------------
    let mut t2 = Table::new(
        "FP8 decode throughput vs batch (s=1024, tok/s)",
        &["batch", "Gaudi2", "H100", "Gaudi2/H100"],
    );
    for b in [1usize, 8, 16, 32, 64, 128, 256] {
        let g = decode_step(model, &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), b, 1024);
        let h = decode_step(model, &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()), b, 1024);
        t2.row(vec![
            b.to_string(),
            f(b as f64 / g.seconds, 0),
            f(b as f64 / h.seconds, 0),
            f(h.seconds / g.seconds, 2),
        ]);
    }
    t2.print();

    // ---- tensor parallelism: thinner GEMMs (§5.6) ----------------
    let mut t3 = Table::new(
        "FP8 decode with tensor parallelism (b=64, s=1024, per-shard tok/s)",
        &["TP", "Gaudi2", "H100"],
    );
    for tp in [1usize, 2, 4, 8] {
        let g = decode_step(
            model,
            &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()).with_tp(tp),
            64, 1024);
        let h = decode_step(
            model,
            &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()).with_tp(tp),
            64, 1024);
        t3.row(vec![
            tp.to_string(),
            f(64.0 / g.seconds, 0),
            f(64.0 / h.seconds, 0),
        ]);
    }
    t3.print();
    println!(
        "(TP shrinks per-device matrices — the §5.6 point that thin-GEMM \
         efficiency, not peak TFLOPS, governs decode)"
    );
}
