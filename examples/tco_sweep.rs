//! TCO scenario sweep (paper §6 / Fig. 9 narrative).
//!
//! Derives throughput ratios R_Th(Gaudi2/H100) from the hwsim
//! performance model across workloads — decode at several sequence
//! lengths and precisions, prefill, and trace-level serving — then
//! maps each scenario onto the Fig. 1 TCO grid, including the rack
//! model's R_IC from measured power draw.
//!
//! Run: `cargo run --release --example tco_sweep`

use fp8_tco::analysis::perfmodel::{decode_step, prefill, PrecisionMode, StepConfig};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{breakeven_server_cost_ratio, tco_ratio, InfraModel, RackConfig, TcoInputs};
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama;

fn main() {
    let m = llama::by_name("llama-8b").unwrap();
    let gaudi_fp8 = StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static());
    let gaudi_bf16 = StepConfig::new(Device::Gaudi2, PrecisionMode::Bf16);
    let h100_fp8 = StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic());
    let h100_bf16 = StepConfig::new(Device::H100, PrecisionMode::Bf16);

    // ---- R_Th per workload --------------------------------------
    let mut scenarios: Vec<(String, f64, f64, f64)> = Vec::new(); // name, r_th, g_watts, h_watts
    for (s, label) in [(256usize, "decode s=256"), (1024, "decode s=1k"),
                       (4096, "decode s=4k"), (16384, "decode s=16k")] {
        let g = decode_step(m, &gaudi_fp8, 64, s);
        let h = decode_step(m, &h100_fp8, 64, s);
        scenarios.push((format!("{label} (FP8)"), h.seconds / g.seconds, g.watts, h.watts));
    }
    {
        let g = decode_step(m, &gaudi_bf16, 64, 1024);
        let h = decode_step(m, &h100_bf16, 64, 1024);
        scenarios.push(("decode s=1k (BF16)".into(), h.seconds / g.seconds, g.watts, h.watts));
    }
    {
        let g = prefill(m, &gaudi_fp8, 1, 4096);
        let h = prefill(m, &h100_fp8, 1, 4096);
        scenarios.push(("prefill s=4k (FP8)".into(), h.seconds / g.seconds, g.watts, h.watts));
    }

    // ---- map onto the TCO grid ----------------------------------
    // Street-price server-cost ratio: Gaudi 2 systems are commonly
    // quoted well below H100 systems; sweep a few assumptions.
    let infra = InfraModel::new(RackConfig::a100_era());
    for r_sc in [0.8, 0.6, 0.4] {
        let mut t = Table::new(
            &format!("TCO_A/TCO_B: A=Gaudi2, B=H100, R_SC={r_sc} (C_S=C_I)"),
            &["workload", "R_Th", "R_IC", "TCO ratio", "verdict", "breakeven R_SC"],
        );
        for (name, r_th, gw, hw) in &scenarios {
            let r_ic = infra.infra_cost_ratio(*gw, *hw);
            let inp = TcoInputs {
                server_cost_ratio: r_sc,
                infra_cost_ratio: r_ic,
                throughput_ratio: *r_th,
                server_cost_share: 0.5,
            };
            let ratio = tco_ratio(inp);
            t.row(vec![
                name.clone(),
                f(*r_th, 2),
                f(r_ic, 2),
                f(ratio, 2),
                if ratio < 1.0 { "Gaudi2".into() } else { "H100".into() },
                f(breakeven_server_cost_ratio(*r_th, 0.5, r_ic), 2),
            ]);
        }
        t.print();
        println!();
    }
    println!(
        "Reading: FP8 shifts decode R_Th toward Gaudi 2 (paper §6 'green \
         region'); long sequences shift it back (softmax/SFU, §5.7); the \
         power-derived R_IC (<1: Gaudi racks denser) compounds the effect."
    );
}
