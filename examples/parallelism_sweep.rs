//! Interconnect-aware parallelism sweep (DESIGN.md §6).
//!
//! Part 1 sweeps (model x device x precision x TP/PP plan) through the
//! HBM capacity check and the comm-aware step model: every row that
//! passes shows its per-chip weight shard, instance KV budget, decode
//! step time with TP all-reduce / PP bubble accounting, and per-chip
//! decode throughput. Rejected plans are listed below the table with
//! their typed `CapacityError` — infeasible configs no longer simulate
//! silently. TP=1/PP=1 rows are *exactly* the paper's single-chip
//! model (the comm terms are zero by construction).
//!
//! Part 2 prices the deployment shape the single-chip model could not
//! express: 70B-class sharded instances serving an open-loop Poisson
//! trace under an interactive SLO, with the surviving goodput priced
//! as $/Mtok via `InfraModel::cost_per_mtok`.
//!
//! Run: `cargo run --release --example parallelism_sweep`
//! (`SWEEP_FAST=1` shrinks the SLO search for smoke tests.)

use fp8_tco::analysis::parallel::{check_step, ParallelismPlan};
use fp8_tco::analysis::perfmodel::{decode_step, PrecisionMode, StepConfig};
use fp8_tco::coordinator::cluster::{
    max_sustainable_qps, sharded_sim_cluster, SloSpec, SweepConfig,
};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{assumed_server_price_usd, InfraModel, RackConfig};
use fp8_tco::util::par::SweepGrid;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama::by_name;
use fp8_tco::workload::trace::TraceConfig;

const DECODE_BATCH: usize = 32;
const DECODE_SEQ: usize = 1024;

fn main() {
    let fast = std::env::var("SWEEP_FAST").ok().as_deref() == Some("1");
    let models = ["llama-8b", "llama-70b"];
    let devices = [Device::H100, Device::Gaudi2, Device::Gaudi3];
    let precisions = [PrecisionMode::Bf16, PrecisionMode::fp8_static()];
    let plans = [
        ParallelismPlan::single(),
        ParallelismPlan::tp(2),
        ParallelismPlan::tp(4),
        ParallelismPlan::tp(8),
        ParallelismPlan::new(4, 2),
    ];

    println!(
        "Capacity-checked TP/PP sweep — decode step (b={DECODE_BATCH}, s={DECODE_SEQ}), \
         BF16 KV.\nTP=1 rows are exactly the single-chip model (zero comm terms).\n"
    );
    let mut t = Table::new(
        "Feasible (model x device x precision x plan) decode operating points",
        &[
            "model",
            "device",
            "precision",
            "plan",
            "chips",
            "W/chip GB",
            "KV Ktok",
            "step ms",
            "TP comm ms",
            "PP bubble",
            "tok/s/chip",
        ],
    );
    let mut rejected: Vec<String> = Vec::new();
    for model in models {
        let m = by_name(model).unwrap();
        for dev in devices {
            for prec in precisions {
                for plan in plans {
                    let w_bytes = prec.weight_bytes_per_elem();
                    // Gate on the *actual* step about to be simulated:
                    // weights/shard + KV(b=32, s=1024) must fit.
                    match check_step(m, dev, plan, w_bytes, 2.0, DECODE_BATCH, DECODE_SEQ) {
                        Err(e) => rejected.push(e.to_string()),
                        Ok(fit) => {
                            let cfg = StepConfig::new(dev, prec).with_plan(plan);
                            let bd = decode_step(m, &cfg, DECODE_BATCH, DECODE_SEQ);
                            let chips = plan.chips_per_instance();
                            let tok_per_chip =
                                DECODE_BATCH as f64 / bd.seconds / chips as f64;
                            t.row(vec![
                                model.into(),
                                dev.name().into(),
                                prec.name().into(),
                                plan.to_string(),
                                chips.to_string(),
                                f(fit.weight_bytes_per_chip / 1e9, 1),
                                f(fit.max_kv_tokens as f64 / 1e3, 0),
                                f(bd.seconds * 1e3, 3),
                                f(bd.t_tp_comm_s * 1e3, 3),
                                f(bd.pp_bubble_frac, 2),
                                f(tok_per_chip, 0),
                            ]);
                        }
                    }
                }
            }
        }
    }
    t.print();
    println!("\nRejected by the HBM capacity check ({} plans):", rejected.len());
    for r in &rejected {
        println!("  - {r}");
    }

    // ---- Part 2: $/Mtok at SLO for sharded 70B deployments ---------
    let slo = SloSpec::interactive();
    let sweep = if fast {
        SweepConfig { iters: 2, n_requests: 30, seed: 13, ..SweepConfig::new(0.25, 8.0) }
    } else {
        SweepConfig { iters: 4, n_requests: 120, seed: 13, ..SweepConfig::new(0.25, 32.0) }
    };
    let infra = InfraModel::new(RackConfig::a100_era());
    println!(
        "\n$/Mtok at SLO (TTFT p95 <= {:.1} s, TPOT p95 <= {:.0} ms; chat trace,\n\
         one sharded instance per cluster, goodput normalized per chip):\n",
        slo.ttft_p95_s,
        slo.tpot_p95_s * 1e3,
    );
    let mut t2 = Table::new(
        "SLO-priced deployments (sharded instances)",
        &[
            "model",
            "device",
            "precision",
            "plan",
            "QPS @SLO",
            "tok/s inst",
            "W/chip",
            "$/Mtok @SLO",
        ],
    );
    let deployments = [
        ("llama-8b", Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::single()),
        ("llama-8b", Device::Gaudi2, PrecisionMode::fp8_static(), ParallelismPlan::single()),
        ("llama-70b", Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::tp(4)),
        ("llama-70b", Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::tp(8)),
        ("llama-70b", Device::Gaudi2, PrecisionMode::fp8_static(), ParallelismPlan::tp(8)),
    ];
    // Independent SLO searches per deployment: evaluate concurrently
    // (PAR=0 forces serial), render in deployment order — the table is
    // byte-identical either way.
    let rows: Vec<Vec<String>> =
        SweepGrid::new(deployments.to_vec()).run(|_, (model, dev, prec, plan)| {
            let m = by_name(model).unwrap();
            let out = max_sustainable_qps(
                &|| {
                    sharded_sim_cluster(m, dev, prec, plan)
                        .unwrap_or_else(|e| panic!("deployment must be feasible: {e}"))
                },
                &TraceConfig::chat,
                &slo,
                &sweep,
            );
            match out.best {
                Some(p) => {
                    // Per-chip goodput scaled to the rack's server shape —
                    // the $/Mtok axis Eq. 1 compares across vendors
                    // (cost_per_mtok under the hood).
                    let cost = infra.cost_per_mtok_sharded(
                        assumed_server_price_usd(dev),
                        plan.total_chips(),
                        p.watts_mean,
                        p.tokens_per_sec,
                    );
                    vec![
                        model.into(),
                        dev.name().into(),
                        prec.name().into(),
                        plan.to_string(),
                        f(p.qps, 2),
                        f(p.tokens_per_sec, 0),
                        f(p.watts_mean, 0),
                        f(cost, 3),
                    ]
                }
                None => vec![
                    model.into(),
                    dev.name().into(),
                    prec.name().into(),
                    plan.to_string(),
                    format!("< {}", sweep.qps_lo),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ],
            }
        });
    for row in rows {
        t2.row(row);
    }
    t2.print();
    println!(
        "\n(the 70B rows are the point of the exercise: which fabric a vendor\n \
         ships decides how much of its single-chip standing survives TP sharding,\n \
         and the $/Mtok-at-SLO column is where that meets Eq. 1)"
    );
}
