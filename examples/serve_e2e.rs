//! End-to-end serving driver (DESIGN.md experiment E2E).
//!
//! Loads the AOT-compiled tiny Llama (FP8 dynamic row-wise linears via
//! the L1 Pallas kernels), serves a batched request trace through the
//! continuous-batching engine over PJRT, and reports latency and
//! throughput. Then replays the *same trace shape* on the simulated
//! Gaudi 2 / H100 backends so the two halves of the system (real
//! compute vs. modelled testbed) are shown side by side.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`

// simlint: allow-file(determinism) -- end-to-end driver timing real PJRT execution with wall-clock

use fp8_tco::analysis::perfmodel::{PrecisionMode, StepConfig};
use fp8_tco::coordinator::{
    Engine, EngineConfig, ExecutionBackend, KvCacheConfig, PjrtBackend, SimBackend,
};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::runtime::ArtifactDir;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama;
use fp8_tco::workload::trace::{Request, TenantClass};

fn trace(n: usize, max_prompt: usize, max_out: usize) -> Vec<Request> {
    use fp8_tco::util::rng::Rng;
    let mut rng = Rng::new(2024);
    (0..n as u64)
        .map(|id| Request {
            id,
            arrival: 0.0,
            prompt_len: rng.usize(4, max_prompt),
            output_len: rng.usize(4, max_out),
            class: TenantClass::Interactive,
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let dir = ArtifactDir::discover();
    anyhow::ensure!(dir.exists(), "run `make artifacts` first");

    // ---------- real serving over PJRT ----------
    let backend = PjrtBackend::load(&dir, "1b")?;
    let meta = backend.meta().clone();
    println!(
        "loaded {} (h={} l={} vocab={} max_seq={}, {})",
        backend.describe(), meta.hidden, meta.layers, meta.vocab,
        meta.max_seq, meta.precision
    );
    let kv = KvCacheConfig { block_tokens: 16, total_blocks: 8192 };
    let mut cfg = EngineConfig::new(kv);
    // b<=2: larger AOT buckets trip an xla_extension 0.5.1 execution
    // bug (sporadic NaN buffers; same HLO is clean under jax's runtime).
    cfg.batcher.max_batch = 2;
    let mut engine = Engine::new(cfg, backend);

    let reqs = trace(24, 30, 48);
    let total_out: usize = reqs.iter().map(|r| r.output_len).sum();
    for r in &reqs {
        engine.submit(r);
    }
    let t0 = std::time::Instant::now();
    anyhow::ensure!(engine.run_to_completion(1_000_000), "engine must drain");
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== E2E (PJRT, real compute) ==");
    println!("{}", engine.metrics.report());
    println!(
        "wall {:.1}s | {} requests | {} tokens | {:.1} tok/s wall | preemptions {}",
        wall,
        reqs.len(),
        total_out,
        engine.metrics.tokens_out as f64 / wall,
        engine.preemptions()
    );
    assert_eq!(engine.metrics.tokens_out as usize, total_out);

    // ---------- same engine code on the simulated testbed ----------
    println!("\n== Same scheduler on the simulated testbed (llama-8b, b<=64) ==");
    let mut t = Table::new(
        "virtual-time serving, 200 chat requests",
        &["device", "precision", "tok/s", "TTFT p50 (s)", "TPOT p50 (ms)", "J/token"],
    );
    for dev in [Device::Gaudi2, Device::H100] {
        for prec in [PrecisionMode::Bf16, PrecisionMode::fp8_static(),
                     PrecisionMode::fp8_dynamic()] {
            let model = llama::by_name("llama-8b").unwrap();
            let kv = KvCacheConfig::from_device(model, dev.spec().hbm_cap, 1.0, 2.0, 16, 0.05);
            let backend = SimBackend::new(model, StepConfig::new(dev, prec));
            let mut cfg = EngineConfig::new(kv);
            cfg.batcher.max_batch = 64;
            let mut e = Engine::new(cfg, backend);
            use fp8_tco::workload::trace::{TraceConfig, TraceGenerator};
            let mut gen = TraceGenerator::new(TraceConfig::chat(50.0), 99);
            for r in gen.take(200) {
                e.submit(&r);
            }
            assert!(e.run_to_completion(10_000_000));
            t.row(vec![
                dev.name().into(),
                prec.name().into(),
                f(e.metrics.tokens_per_sec(), 0),
                f(e.metrics.ttft.pct(50.0), 3),
                f(e.metrics.tpot.pct(50.0) * 1e3, 2),
                f(e.metrics.joules_per_token(), 2),
            ]);
        }
    }
    t.print();
    println!("(the FP8 rows are the paper's §6 TCO argument in action: \
              Gaudi 2 gains ~1.5x from FP8, the H100 little)");
    Ok(())
}
