//! Open-loop SLO load sweep (DESIGN.md §5, experiment SLO-TCO).
//!
//! For each device×precision, a 2-engine cluster serves a seeded
//! Poisson chat trace on one shared virtual clock; a binary search
//! finds the max sustainable QPS whose *steady-state* TTFT p95 stays
//! under 2 s and TPOT p95 under 50 ms. The SLO-feasible goodput is
//! then priced with the rack/infra model as cost per million output
//! tokens — the paper's Eq. 1 with throughput measured under a latency
//! constraint instead of at peak.
//!
//! Run: `cargo run --release --example load_sweep`
//! (`SWEEP_FAST=1` shrinks the search for smoke tests.)

use fp8_tco::analysis::perfmodel::PrecisionMode;
use fp8_tco::coordinator::cluster::{max_sustainable_qps, sim_cluster, SloSpec, SweepConfig};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{assumed_server_price_usd, InfraModel, RackConfig};
use fp8_tco::util::par::SweepGrid;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::trace::TraceConfig;

const N_ENGINES: usize = 2;

fn main() {
    let slo = SloSpec::interactive();
    let sweep = if std::env::var("SWEEP_FAST").ok().as_deref() == Some("1") {
        SweepConfig { iters: 2, n_requests: 40, ..SweepConfig::new(0.5, 16.0) }
    } else {
        SweepConfig::new(0.5, 64.0)
    };
    let infra = InfraModel::new(RackConfig::a100_era());
    let chips = infra.rack.chips_per_server as f64;
    println!(
        "Max sustainable QPS under TTFT p95 <= {:.1} s / TPOT p95 <= {:.0} ms\n\
         (llama-8b Poisson chat trace, {N_ENGINES}-engine cluster, one shared \
         virtual clock, steady-state window)\n",
        slo.ttft_p95_s,
        slo.tpot_p95_s * 1e3,
    );
    let mut t = Table::new(
        "SLO-constrained serving cost",
        &[
            "device",
            "precision",
            "max QPS",
            "tok/s",
            "TTFT p95 (s)",
            "TPOT p95 (ms)",
            "W/chip",
            "$/Mtok @SLO",
        ],
    );
    // Each (device x precision) cell is an independent SLO search on
    // its own fresh cluster: evaluate the grid concurrently (PAR=0
    // forces serial) and render rows in grid order — the printed table
    // is byte-identical either way.
    let grid: Vec<(Device, PrecisionMode)> = [Device::Gaudi2, Device::H100]
        .iter()
        .flat_map(|&dev| {
            [
                PrecisionMode::Bf16,
                PrecisionMode::fp8_static(),
                PrecisionMode::fp8_dynamic(),
            ]
            .iter()
            .map(move |&prec| (dev, prec))
            .collect::<Vec<_>>()
        })
        .collect();
    let rows: Vec<Vec<String>> = SweepGrid::new(grid).run(|_, (dev, prec)| {
        let out = max_sustainable_qps(
            &|| sim_cluster(dev, prec, N_ENGINES),
            &TraceConfig::chat,
            &slo,
            &sweep,
        );
        match out.best {
            Some(p) => {
                let per_chip_tps = p.tokens_per_sec / N_ENGINES as f64;
                let cost = infra.cost_per_mtok(
                    assumed_server_price_usd(dev),
                    p.watts_mean,
                    per_chip_tps * chips,
                );
                vec![
                    dev.name().into(),
                    prec.name().into(),
                    f(p.qps, 2),
                    f(p.tokens_per_sec, 0),
                    f(p.ttft_p95, 3),
                    f(p.tpot_p95 * 1e3, 2),
                    f(p.watts_mean, 0),
                    f(cost, 3),
                ]
            }
            None => vec![
                dev.name().into(),
                prec.name().into(),
                format!("< {}", sweep.qps_lo),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ],
        }
    });
    for row in rows {
        t.row(row);
    }
    t.print();
    println!(
        "\n(goodput-at-SLO, not peak tok/s, enters Eq. 1 here: the FP8 rows move\n \
         both the throughput ratio and — via lower sustained draw and denser\n \
         power-limited racks — the infra-cost share)"
    );
}
