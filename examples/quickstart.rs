//! Quickstart: the three layers in one page.
//!
//! 1. TCO math (Eq. 1) — pure rust.
//! 2. Hardware simulation — time an FP8 GEMM on both devices.
//! 3. Real compute — load the AOT artifacts through PJRT and generate
//!    a few tokens with the FP8-quantized tiny Llama.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use fp8_tco::analysis::perfmodel::{decode_step, PrecisionMode, StepConfig};
use fp8_tco::coordinator::{ExecutionBackend, PjrtBackend};
use fp8_tco::hwsim::gemm::{gemm_time, GemmConfig};
use fp8_tco::hwsim::spec::{Accum, Device, Scaling};
use fp8_tco::runtime::ArtifactDir;
use fp8_tco::tco::{tco_ratio, TcoInputs};
use fp8_tco::workload::llama;

fn main() -> anyhow::Result<()> {
    // --- 1. TCO (paper Eq. 1) -------------------------------------
    println!("## 1. TCO model");
    let r = tco_ratio(TcoInputs::fig1(0.5, 0.8));
    println!(
        "System A at half the server cost and 0.8x throughput: \
         TCO_A/TCO_B = {r:.2} -> {}",
        if r < 1.0 { "A wins" } else { "B wins" }
    );

    // --- 2. Hardware simulation ------------------------------------
    println!("\n## 2. Simulated testbed (thin GEMM, the decode shape)");
    for dev in [Device::Gaudi2, Device::H100] {
        let accum = if dev == Device::H100 { Accum::Fast } else { Accum::Fp32 };
        let bf16 = gemm_time(dev, 64, 4096, 4096, GemmConfig::bf16());
        let fp8 = gemm_time(dev, 64, 4096, 4096, GemmConfig::fp8(Scaling::PerRow, accum));
        println!(
            "{:>7}: bf16 {:6.1} TFLOPS | fp8 {:6.1} TFLOPS | fp8 gain {:.2}x",
            dev.name(),
            bf16.tflops(),
            fp8.tflops(),
            bf16.seconds / fp8.seconds
        );
    }
    let m = llama::by_name("llama-8b").unwrap();
    let step = decode_step(m, &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), 64, 1024);
    println!(
        "llama-8b decode b=64 s=1024 on sim-Gaudi2/FP8: {:.2} ms/step, {:.0} tok/s",
        step.seconds * 1e3,
        64.0 / step.seconds
    );

    // --- 3. Real compute through PJRT ------------------------------
    println!("\n## 3. PJRT (real compute, FP8 Pallas kernels inside)");
    let dir = ArtifactDir::discover();
    if !dir.exists() {
        println!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    let mut backend = PjrtBackend::load(&dir, "1b")?;
    println!("loaded: {}", backend.describe());
    let pre = backend.prefill(&[(0, 16)]);
    println!("prefill(16 tokens): {:.1} ms", pre.seconds * 1e3);
    for i in 0..8 {
        let d = backend.decode(&[(0, 16 + 1 + i)]);
        print!("{} ", backend.emitted[&0].last().unwrap());
        let _ = d;
    }
    println!("\ngenerated 1+8 tokens greedily — all layers composed.");
    Ok(())
}
