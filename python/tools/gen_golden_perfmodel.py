#!/usr/bin/env python3
"""Generate rust/tests/golden/perfmodel.json from a stdlib-only mirror
of the Rust perf model.

This is the same discipline as tests/test_kv_transfer_mirror.py: an
independent implementation of the closed-form model, kept in lock-step
with rust/src/analysis/perfmodel.rs (and the hwsim gemm/mme/softmax/
power models it composes), so the golden snapshot is produced by a
second implementation rather than by the code under test. The Rust
side (tests/golden_perfmodel.rs) compares at 1e-9 relative tolerance,
which comfortably absorbs libm ulp differences between the two
runtimes while pinning every structural term.

Every function here mirrors its Rust namesake operation-for-operation
(same associativity, same integer divisions) — do not "simplify" the
arithmetic: x / a / b and x / (a * b) differ in the last ulp, and the
point of the mirror is bit-level agreement up to libm.

Run from the repo root:  python3 python/tools/gen_golden_perfmodel.py
"""

import json
import math
import os

# --------------------------------------------------------------- spec.rs

DEVICES = {
    # name: (peak_fp8, peak_bf16, hbm_bw, vector_flops, has_sfu,
    #        tdp, idle_w, engine, clock_hz)
    "H100": dict(
        peak_fp8=1989.9e12, peak_bf16=989.4e12, hbm_bw=3.35e12,
        vector_flops=133.8e12, has_sfu=True, tdp=700.0, idle_w=90.0,
        engine=("many_small", dict(units=528, feed_rate=1.05e12, tile=128)),
        clock_hz=1.59e9,
    ),
    "Gaudi2": dict(
        peak_fp8=865.0e12, peak_bf16=432.0e12, hbm_bw=2.4e12,
        vector_flops=11.0e12, has_sfu=False, tdp=600.0, idle_w=100.0,
        engine=("large_systolic", dict(
            units=2, pes_per_unit=256 * 256,
            geometries=[(256, 256), (128, 512), (512, 128)])),
        clock_hz=1.65e9,
    ),
    "Gaudi3": dict(
        peak_fp8=1835.0e12, peak_bf16=1835.0e12, hbm_bw=3.7e12,
        vector_flops=28.7e12, has_sfu=False, tdp=900.0, idle_w=120.0,
        engine=("large_systolic", dict(
            units=8, pes_per_unit=256 * 256,
            geometries=[(256, 256), (128, 512), (512, 128)])),
        clock_hz=1.6e9,
    ),
    "A100": dict(
        peak_fp8=624.0e12, peak_bf16=312.0e12, hbm_bw=2.04e12,
        vector_flops=78.0e12, has_sfu=True, tdp=400.0, idle_w=60.0,
        engine=("many_small", dict(units=432, feed_rate=0.7e12, tile=128)),
        clock_hz=1.41e9,
    ),
}

DTYPE_BYTES = {"bf16": 2.0, "fp8": 1.0}


def peak(dev, dtype):
    return DEVICES[dev]["peak_fp8"] if dtype == "fp8" else DEVICES[dev]["peak_bf16"]


# -------------------------------------------------------------- calib.rs

def launch_overhead(dev):
    return {"H100": 7.5e-6, "A100": 9.0e-6, "Gaudi2": 2.2e-6, "Gaudi3": 2.2e-6}[dev]


def mfu_cap_fp8(dev, scaling, accum):
    if dev in ("H100", "A100"):
        if scaling == "per_row":
            return 0.21 if accum == "fp32" else 0.58
        return 0.67 if accum == "fp32" else 0.71
    # Gaudi: accumulation is always FP32 in the MME, cap keyed on scaling.
    if scaling == "per_row":
        return 0.90
    if scaling == "hw_pow2":
        return 1.0
    return 0.985


def mfu_cap_bf16(dev):
    return 0.72 if dev in ("H100", "A100") else 0.95


def h100_ramp_midpoint(scaling, dtype):
    if dtype == "bf16":
        return 1100.0
    return 1150.0 if scaling == "per_row" else 1750.0


H100_RAMP_POWER = 3.0
GAUDI_TPC_QUANT_RATE = 5.5e12
EXP_FLOP_EQUIV = 4.0


def hbm_stream_eff(dev):
    return 0.83 if dev in ("H100", "A100") else 0.78


def power_curve(dev):
    return {
        "H100": (1.63, 0.62, 1.0),
        "A100": (1.5, 0.62, 1.0),
        "Gaudi2": (0.78, 0.41, 0.80),
        "Gaudi3": (0.80, 0.45, 0.85),
    }[dev]


def sfu_exp_rate(dev):
    return {"H100": 3.4e12, "A100": 2.4e12}.get(dev, 0.0)


# -------------------------------------------------------------- power.rs

DVFS_POWER = 2.2


def power_draw_w(dev, util_frac):
    spec = DEVICES[dev]
    a, b, max_frac = power_curve(dev)
    frac = min(a * max(util_frac, 0.0) ** b, max_frac)
    return spec["idle_w"] + (spec["tdp"] - spec["idle_w"]) * frac


def apply_cap(dev, cap_w, t_s, util_frac, compute_frac):
    """Mirror of power::apply_cap, clamp + cap_feasible flag included:
    when the cap is feasible (cap_w >= idle_w) the reported draw never
    exceeds cap_w — the DVFS floors' residual is duty-cycled away."""
    spec = DEVICES[dev]
    p0 = power_draw_w(dev, util_frac)
    if p0 <= cap_w:
        return dict(clock_frac=1.0, seconds=t_s, watts=p0, cap_feasible=True)
    dyn0 = p0 - spec["idle_w"]
    cap_feasible = cap_w >= spec["idle_w"]
    target_dyn = max(cap_w - spec["idle_w"], dyn0 * 0.05)
    f = min(max((target_dyn / dyn0) ** (1.0 / DVFS_POWER), 0.2), 1.0)
    seconds = t_s * (compute_frac / f + (1.0 - compute_frac))
    watts = spec["idle_w"] + dyn0 * f ** DVFS_POWER
    if cap_feasible:
        watts = min(watts, cap_w)
    return dict(clock_frac=f, seconds=seconds, watts=watts,
                cap_feasible=cap_feasible)


def rack_allocation(total_w, demands):
    """Mirror of power::rack_allocation (water-filling; Python's sort
    is stable like Rust's sort_by, so ties break identically)."""
    n = len(demands)
    if n == 0:
        return []
    if sum(demands) <= total_w:
        return list(demands)
    alloc = [0.0] * n
    remaining = total_w
    left = n
    for i in sorted(range(n), key=lambda j: demands[j]):
        fair = remaining / left
        give = min(demands[i], fair)
        alloc[i] = give
        remaining -= give
        left -= 1
    return alloc


# ---------------------------------------------------------------- mme.rs

def macs_per_pe(dev, dtype):
    spec = DEVICES[dev]
    kind, e = spec["engine"]
    if kind == "large_systolic":
        return peak(dev, dtype) / (e["units"] * e["pes_per_unit"] * 2.0 * spec["clock_hz"])
    return 1.0


def div_ceil(a, b):
    return -(-a // b)


def mme_cycles(m, k, n, units, geometries, macs):
    fp8_boost = macs
    best = None  # (cycles, geometry)
    for rows, cols in geometries:
        tiles_m = div_ceil(m, rows)
        tiles_n = div_ceil(n, cols)
        tiles = float(tiles_m * tiles_n)
        tiles_per_unit = math.ceil(tiles / units)
        stream = max(k / fp8_boost, 1.0)
        bubble = float(rows + cols)
        cycles = tiles_per_unit * (stream + bubble)
        if best is None or cycles < best[0]:
            best = (cycles, (rows, cols))
    return best


def ceil_frac(dim, tile):
    padded = div_ceil(dim, tile) * tile
    return dim / padded


# --------------------------------------------------------------- gemm.rs
# GemmConfig mirror: (dtype, scaling, accum) tuples.

GEMM_BF16 = ("bf16", "per_tensor", "fp32")


def gemm_time(dev, m, k, n, cfg):
    dtype, scaling, accum = cfg
    spec = DEVICES[dev]
    flops = 2.0 * m * k * n
    in_bytes = (m * k + k * n) * DTYPE_BYTES[dtype]
    out_bytes = (m * n) * 2.0
    in_elems = float(m * k + k * n)

    t_hbm = (in_bytes + out_bytes) / (spec["hbm_bw"] * hbm_stream_eff(dev))

    kind, e = spec["engine"]
    if kind == "large_systolic":
        macs = macs_per_pe(dev, dtype)
        cycles, (rows, cols) = mme_cycles(
            m, k, n, e["units"], e["geometries"], macs)
        if dtype == "fp8":
            cap = mfu_cap_fp8(dev, scaling, "fp32")
        else:
            cap = mfu_cap_bf16(dev)
        t_compute = cycles / spec["clock_hz"] / cap
        feed_rate = e["units"] * float(rows + cols) * spec["clock_hz"]
        t_feed = in_elems / feed_rate
    else:  # many_small
        if dtype == "fp8":
            cap = mfu_cap_fp8(dev, scaling, accum)
        else:
            cap = mfu_cap_bf16(dev)
        feed_rate = e["feed_rate"]
        if dtype == "fp8" and scaling == "per_row":
            feed_rate = feed_rate * 1.12
        elif dtype == "fp8":
            feed_rate = feed_rate * 1.05
        m_eff = float(max(m, e["tile"]))
        s_eff = (m_eff * k * n) ** (1.0 / 3.0)
        mid = h100_ramp_midpoint(scaling, dtype)
        ramp = 1.0 / (1.0 + (mid / s_eff) ** H100_RAMP_POWER)
        align = max(ceil_frac(m, e["tile"]), 0.25) * max(ceil_frac(n, e["tile"]), 0.25)
        eff = max(cap * ramp * align, 1e-4)
        t_compute = flops / (peak(dev, dtype) * eff)
        t_feed = in_elems / feed_rate

    if dtype == "fp8" and scaling == "per_row" and dev in ("Gaudi2", "Gaudi3"):
        t_quant = (m * k) / GAUDI_TPC_QUANT_RATE
    else:
        t_quant = 0.0

    t_launch = launch_overhead(dev)
    body = max(t_compute, max(t_hbm, t_feed))
    seconds = t_launch + body + t_quant
    bound = max(t_compute, max(t_hbm, t_feed))
    if bound == t_compute:
        bound_by = "compute"
    elif bound == t_hbm:
        bound_by = "hbm"
    else:
        bound_by = "feed"
    return dict(seconds=seconds, t_launch=t_launch, bound_by=bound_by)


# ------------------------------------------------------------ softmax.rs

def exp_time(dev, n_exp, overlap_budget):
    spec = DEVICES[dev]
    if spec["has_sfu"]:
        t = n_exp / sfu_exp_rate(dev)
        return max(t - overlap_budget, 0.0)
    return n_exp * EXP_FLOP_EQUIV / spec["vector_flops"]


def decode_exp_count(batch, seq, heads):
    return float(batch) * float(seq) * float(heads)


def prefill_exp_count(batch, seq, heads):
    s = float(seq)
    return float(batch) * (s * s / 2.0) * float(heads)


# ------------------------------------------------------- interconnect.rs

INTERCONNECT = {
    "H100": dict(scale_up_bw=450.0e9, scale_up_lat_s=1.0e-6, scale_up_domain=8,
                 scale_out_bw=50.0e9, scale_out_lat_s=5.0e-6),
    "A100": dict(scale_up_bw=300.0e9, scale_up_lat_s=1.3e-6, scale_up_domain=8,
                 scale_out_bw=25.0e9, scale_out_lat_s=6.0e-6),
    "Gaudi2": dict(scale_up_bw=262.5e9, scale_up_lat_s=3.0e-6, scale_up_domain=8,
                   scale_out_bw=37.5e9, scale_out_lat_s=6.0e-6),
    "Gaudi3": dict(scale_up_bw=525.0e9, scale_up_lat_s=2.5e-6, scale_up_domain=8,
                   scale_out_bw=75.0e9, scale_out_lat_s=5.0e-6),
}


def group_link(ic, n):
    if n <= ic["scale_up_domain"]:
        return ic["scale_up_bw"], ic["scale_up_lat_s"]
    return ic["scale_out_bw"], ic["scale_out_lat_s"]


def allreduce_time_s(ic, n, nbytes):
    if n <= 1:
        return 0.0
    bw, lat = group_link(ic, n)
    steps = float(n - 1)
    return 2.0 * steps / n * nbytes / bw + 2.0 * steps * lat


def p2p_time_s(ic, nbytes, within_scale_up):
    if within_scale_up:
        bw, lat = ic["scale_up_bw"], ic["scale_up_lat_s"]
    else:
        bw, lat = ic["scale_out_bw"], ic["scale_out_lat_s"]
    return nbytes / bw + lat


# -------------------------------------------------------------- llama.rs

MODELS = {
    "llama-8b": dict(hidden=4096, layers=32, heads=32, kv_heads=8,
                     intermediate=14336, vocab=128256),
    "llama-70b": dict(hidden=8192, layers=80, heads=64, kv_heads=8,
                      intermediate=28672, vocab=128256),
}


def head_dim(m):
    return m["hidden"] // m["heads"]


def a_const(m):
    mlp_ratio = m["intermediate"] / m["hidden"]
    gqa_groups = m["heads"] / m["kv_heads"]
    return 3.0 * mlp_ratio + 2.0 + 2.0 / gqa_groups


def prefill_flops(m, s):
    h, l, v = float(m["hidden"]), float(m["layers"]), float(m["vocab"])
    s = float(s)
    return 2.0 * s * h * h * l * a_const(m) + 2.0 * s * s * h * l + 2.0 * v * s * h


def decode_step_flops(m, context_lens):
    h, l, v = float(m["hidden"]), float(m["layers"]), float(m["vocab"])
    b = float(len(context_lens))
    sum_s = 0.0
    for s in context_lens:
        sum_s += float(s)
    return 2.0 * b * (a_const(m) * h * h * l + v * h) + 4.0 * h * l * sum_s


# ----------------------------------------------------------- perfmodel.rs

PRECISIONS = {
    # name -> (dtype, scaling, accum) of the block linears; None = bf16
    "bf16": GEMM_BF16,
    "fp8-static": ("fp8", "static", "fast"),
    "fp8-dynamic": ("fp8", "per_row", "fast"),
}


def decode_work(m, dev, prec, tp, kv_bytes, batch, seq):
    h = m["hidden"]
    kv_shard = max(min(tp, m["kv_heads"]), 1)
    kv_dim = m["kv_heads"] * head_dim(m) // kv_shard
    inter = m["intermediate"] // tp
    gcfg = PRECISIONS[prec]

    shapes = [
        (batch, h, h // tp),
        (batch, h, kv_dim),
        (batch, h, kv_dim),
        (batch, h // tp, h),
        (batch, h, inter),
        (batch, h, inter),
        (batch, inter, h),
    ]
    t_lin = 0.0
    lin_compute_frac_acc = 0.0
    for mm, kk, nn in shapes:
        bd = gemm_time(dev, mm, kk, nn, gcfg)
        t_lin += bd["seconds"]
        lin_compute_frac_acc += bd["seconds"] * (0.0 if bd["bound_by"] == "hbm" else 1.0)
    t_lin *= float(m["layers"])
    lin_compute_frac_acc *= float(m["layers"])

    kv_bytes_layer = 2.0 * batch * float(seq) * float(kv_dim) * kv_bytes
    spec = DEVICES[dev]
    t_kv_layer = kv_bytes_layer / (spec["hbm_bw"] * hbm_stream_eff(dev))
    t_kv = t_kv_layer * float(m["layers"])

    heads = m["heads"] // tp
    n_exp = decode_exp_count(batch, seq, heads) * float(m["layers"])
    overlap = t_lin + t_kv
    t_exp = exp_time(dev, n_exp, overlap)

    head = gemm_time(dev, batch, h, m["vocab"] // tp, GEMM_BF16)
    t_head = head["seconds"]

    return dict(
        t_raw=t_lin + t_kv + t_exp + t_head,
        t_lin=t_lin, t_kv=t_kv, t_exp=t_exp, t_head=t_head,
        lin_compute_frac_acc=lin_compute_frac_acc,
    )


def resolve_mb(pp, microbatches, tokens):
    if pp == 1:
        return 1
    want = microbatches if microbatches > 0 else pp
    return max(1, min(want, max(tokens, 1)))


def finish(dev, prec, tp, pp, t_raw, util, flops,
           t_lin, t_kv, t_exp, t_head, tokens, hidden, layers, mb, t_work_mb_raw,
           power_cap=None, compute_frac=1.0):
    # Mirror of the PowerCap arms. None: no stretch, draw at the
    # utilization point. ("per_gpu", w): apply_cap. ("per_rack", w, n):
    # water-fill the uniform demand vector, then apply_cap at the
    # (degenerate even) share — exactly the Rust arm.
    if power_cap is None:
        t_work = t_raw
        watts = power_draw_w(dev, util)
    elif power_cap[0] == "per_gpu":
        capped = apply_cap(dev, power_cap[1], t_raw, util, compute_frac)
        t_work, watts = capped["seconds"], capped["watts"]
    else:
        p0 = power_draw_w(dev, util)
        alloc = rack_allocation(power_cap[1], [p0] * max(power_cap[2], 1))
        per = alloc[0] if alloc else power_cap[1]
        capped = apply_cap(dev, per, t_raw, util, compute_frac)
        t_work, watts = capped["seconds"], capped["watts"]

    ic = INTERCONNECT[dev]
    chips = tp * pp

    mb = max(mb, 1)
    tokens_per_mb = div_ceil(tokens, mb)
    act_bytes = tokens_per_mb * float(hidden) * 2.0

    if tp > 1:
        t_tp_mb = 2.0 * float(layers) * allreduce_time_s(ic, tp, act_bytes)
    else:
        t_tp_mb = 0.0

    stretch = t_work / t_raw if t_raw > 0.0 else 1.0

    if pp == 1:
        seconds = t_work + t_tp_mb
        t_tp_comm, t_pp_comm, pp_bubble_frac = t_tp_mb, 0.0, 0.0
    else:
        hop = p2p_time_s(ic, act_bytes, chips <= ic["scale_up_domain"])
        slots = float(mb + pp - 1)
        ppf = float(pp)
        slot_time = (t_work_mb_raw * stretch + t_tp_mb) / ppf + hop
        seconds = slots * slot_time
        t_tp_comm = slots * t_tp_mb / ppf
        t_pp_comm = slots * hop
        pp_bubble_frac = float(pp - 1) / slots

    flops_per_chip = flops / pp
    return dict(
        seconds=seconds,
        t_linears_s=t_lin,
        t_attention_kv_s=t_kv,
        t_softmax_s=t_exp,
        t_lm_head_s=t_head,
        t_tp_comm_s=t_tp_comm,
        t_pp_comm_s=t_pp_comm,
        pp_bubble_frac=pp_bubble_frac,
        flops=flops_per_chip,
        achieved_flops=flops_per_chip / seconds,
        util_frac=util,
        watts=watts,
    )


def decode_step(m, dev, prec, tp, pp, batch, seq, kv_bytes=2.0, power_cap=None):
    tp = max(tp, 1)
    w = decode_work(m, dev, prec, tp, kv_bytes, batch, seq)

    lens = [seq] * batch
    flops = decode_step_flops(m, lens) / tp
    dtype = PRECISIONS[prec][0]
    pk = peak(dev, dtype)
    util = min(flops / w["t_raw"] / pk, 1.0)
    compute_frac = (w["lin_compute_frac_acc"] + w["t_exp"]) / w["t_raw"]

    mb = resolve_mb(max(pp, 1), 0, batch)
    if max(pp, 1) == 1:
        t_work_mb_raw = w["t_raw"]
    else:
        t_work_mb_raw = decode_work(m, dev, prec, tp, kv_bytes,
                                    div_ceil(batch, mb), seq)["t_raw"]

    return finish(dev, prec, tp, max(pp, 1), w["t_raw"], util, flops,
                  w["t_lin"], w["t_kv"], w["t_exp"], w["t_head"],
                  batch, m["hidden"], m["layers"], mb, t_work_mb_raw,
                  power_cap=power_cap, compute_frac=compute_frac)


def prefill(m, dev, prec, tp, pp, batch, seq, power_cap=None):
    tp = max(tp, 1)
    h = m["hidden"]
    kv_shard = max(min(tp, m["kv_heads"]), 1)
    kv_dim = m["kv_heads"] * head_dim(m) // kv_shard
    inter = m["intermediate"] // tp
    gcfg = PRECISIONS[prec]
    mm = batch * seq

    shapes = [
        (mm, h, h // tp),
        (mm, h, kv_dim),
        (mm, h, kv_dim),
        (mm, h // tp, h),
        (mm, h, inter),
        (mm, h, inter),
        (mm, inter, h),
    ]
    t_lin = 0.0
    for a, b, c in shapes:
        t_lin += gemm_time(dev, a, b, c, gcfg)["seconds"]
    t_lin *= float(m["layers"])

    d = head_dim(m)
    heads = m["heads"] // tp
    per_head = gemm_time(dev, seq, d, seq, GEMM_BF16)
    body = per_head["seconds"] - per_head["t_launch"]
    t_attn_layer = body * float(heads * batch) * 2.0 * 0.5 + per_head["t_launch"]
    t_attn = t_attn_layer * float(m["layers"])

    n_exp = prefill_exp_count(batch, seq, heads) * float(m["layers"])
    overlap = t_lin + t_attn
    t_exp = exp_time(dev, n_exp, overlap)

    head = gemm_time(dev, mm, h, m["vocab"] // tp, GEMM_BF16)
    t_head = head["seconds"]

    t_raw = t_lin + t_attn + t_exp + t_head
    flops = float(batch) * prefill_flops(m, seq) / tp
    dtype = PRECISIONS[prec][0]
    pk = peak(dev, dtype)
    util = min(flops / t_raw / pk, 1.0)
    mb = resolve_mb(max(pp, 1), 0, mm)
    t_work_mb_raw = t_raw / float(mb)
    return finish(dev, prec, tp, max(pp, 1), t_raw, util, flops,
                  t_lin, t_attn, t_exp, t_head,
                  mm, h, m["layers"], mb, t_work_mb_raw,
                  power_cap=power_cap, compute_frac=0.95)


# ------------------------------------------------------------------ grid
# Mirrors grid() in rust/tests/golden_perfmodel.rs exactly.

def grid():
    m8 = MODELS["llama-8b"]
    m70 = MODELS["llama-70b"]
    out = {}
    for dev in ["H100", "Gaudi2", "Gaudi3", "A100"]:
        for prec in ["bf16", "fp8-static", "fp8-dynamic"]:
            for tp, pp in [(1, 1), (2, 1), (8, 1), (1, 2), (4, 2)]:
                key = f"{dev}|{prec}|tp{tp}-pp{pp}"
                out[f"{key}|decode-8b-b32-s1024"] = decode_step(
                    m8, dev, prec, tp, pp, 32, 1024)
                out[f"{key}|prefill-8b-b1-s2048"] = prefill(
                    m8, dev, prec, tp, pp, 1, 2048)
    for dev in ["H100", "Gaudi2"]:
        out[f"{dev}|fp8-static|tp4-pp1|decode-70b-b32-s1024"] = decode_step(
            m70, dev, "fp8-static", 4, 1, 32, 1024)
        out[f"{dev}|fp8-static|tp4-pp2|decode-70b-b32-s1024"] = decode_step(
            m70, dev, "fp8-static", 4, 2, 32, 1024)
        out[f"{dev}|fp8-static|tp4-pp2|prefill-70b-b1-s2048"] = prefill(
            m70, dev, "fp8-static", 4, 2, 1, 2048)
    return out


def main():
    root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    path = os.path.join(root, "rust", "tests", "golden", "perfmodel.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    snap = grid()
    assert len(snap) == 126, f"grid size {len(snap)} != 126"
    for key, bd in snap.items():
        for field, v in bd.items():
            assert math.isfinite(v), f"{key}.{field} = {v}"
    with open(path, "w") as f:
        json.dump(snap, f, sort_keys=True, separators=(",", ":"))
        f.write("\n")
    print(f"wrote {path} ({len(snap)} entries)")


if __name__ == "__main__":
    main()
