"""Python mirror of the simulator's RNG (`util::rng::Rng`): xoshiro256**
seeded by SplitMix64, the Lemire multiply-shift bounded-range rule, and
the 53-bit f64 stream.

Both sides draw the same streams and assert the same pinned values
(PINNED_* below mirror `rust/src/util/rng.rs::range_pinned_against_python_mirror`,
`::range_rejection_path_pinned` and `::f64_stream_unchanged_by_range_fix`).
The pins are what make trace generation reproducible across the Lemire
fix: seeded arrival streams must be byte-identical on both sides, and
if either implementation drifts, its side fails against the pins.

Stdlib-only on purpose (CI runs it without the JAX toolchain):
`python python/tests/test_trace_mirror.py`.
"""

M = (1 << 64) - 1


def splitmix_seed(seed):
    """SplitMix64 expansion of a 64-bit seed into the xoshiro state —
    mirrors `Rng::new` (same constants, same order)."""
    s = []
    x = (seed + 0x9E3779B97F4A7C15) & M
    for _ in range(4):
        x = (x + 0x9E3779B97F4A7C15) & M
        z = x
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M
        s.append(z ^ (z >> 31))
    return s


def rotl(v, k):
    return ((v << k) | (v >> (64 - k))) & M


class Rng:
    """xoshiro256** — mirrors `Rng::next_u64` exactly."""

    def __init__(self, seed):
        self.s = splitmix_seed(seed)

    def next_u64(self):
        s = self.s
        r = (rotl((s[1] * 5) & M, 7) * 9) & M
        t = (s[1] << 17) & M
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return r

    def f64(self):
        """53-bit mantissa uniform in [0, 1) — mirrors `Rng::f64`."""
        return (self.next_u64() >> 11) * 2.0**-53

    def range(self, lo, hi):
        """Lemire multiply-shift with rejection — mirrors `Rng::range`.
        Returns (value, rejections) so the rejection path itself can be
        pinned."""
        assert lo < hi
        span = hi - lo
        threshold = ((1 << 64) - span) % span  # span.wrapping_neg() % span
        rejections = 0
        while True:
            x = self.next_u64()
            m = x * span
            if (m & M) >= threshold:
                return lo + (m >> 64), rejections
            rejections += 1


# seed -> first 4 raw next_u64 draws (the stream every f64 — and hence
# every trace timestamp and length — is carved from).
PINNED_U64 = {
    42: [
        13696896915399030466,
        12641092763546669283,
        14580102322132234639,
        5279892052835703538,
    ],
}

# (seed, lo, hi) -> pinned range() draws.
PINNED_RANGE = [
    (11, 10, 20, [11, 17, 15, 14, 14, 13, 11, 16]),
    (5, 0, 10**12, [404794302180, 463519180289, 747084197040, 302323474737]),
]

# Span just above 2^63: threshold ~ 2^63, so ~half of all draws reject
# — this pins the rejection loop, not just the happy path.
REJECTION_SPAN = (1 << 63) + 12345
PINNED_REJECTION = [
    6036662480048362042,
    14850985635934019,
    2634583529135477697,
    6166093495432743727,
]
PINNED_REJECTION_COUNT = 8  # across the first 16 draws at seed 123


def test_next_u64_pins():
    for seed, want in PINNED_U64.items():
        r = Rng(seed)
        got = [r.next_u64() for _ in range(len(want))]
        assert got == want, f"seed {seed}: {got} != pinned {want}"


def test_range_matches_pinned_rust_values():
    for seed, lo, hi, want in PINNED_RANGE:
        r = Rng(seed)
        got = [r.range(lo, hi)[0] for _ in range(len(want))]
        assert got == want, f"seed {seed} range({lo},{hi}): {got} != {want}"
        assert all(lo <= v < hi for v in got)


def test_rejection_path_matches_pinned_rust_values():
    r = Rng(123)
    vals, rejections = [], 0
    for _ in range(16):
        v, rj = r.range(0, REJECTION_SPAN)
        vals.append(v)
        rejections += rj
    assert vals[:4] == PINNED_REJECTION, f"{vals[:4]} != {PINNED_REJECTION}"
    assert rejections == PINNED_REJECTION_COUNT, (
        f"rejection loop drifted: {rejections} != {PINNED_REJECTION_COUNT}"
    )
    assert all(v < REJECTION_SPAN for v in vals)


def test_f64_stream_rides_only_the_u64_stream():
    # The f64 mapping is (next_u64 >> 11) * 2^-53, nothing else — so
    # the pinned u64 stream fully determines every trace draw.
    r = Rng(42)
    got = [r.f64() for _ in range(4)]
    want = [(u >> 11) * 2.0**-53 for u in PINNED_U64[42]]
    assert got == want
    assert all(0.0 <= x < 1.0 for x in got)


def test_range_is_unbiased_over_small_span():
    # Mirrors `range_unbiased_over_small_span`: Lemire over span 3 must
    # split ~evenly (a dropped rejection threshold skews this grossly).
    r = Rng(31)
    counts = [0, 0, 0]
    for _ in range(30_000):
        counts[r.range(0, 3)[0]] += 1
    assert all(9_000 <= c <= 11_000 for c in counts), counts


def main():
    tests = [
        test_next_u64_pins,
        test_range_matches_pinned_rust_values,
        test_rejection_path_matches_pinned_rust_values,
        test_f64_stream_rides_only_the_u64_stream,
        test_range_is_unbiased_over_small_span,
    ]
    for t in tests:
        t()
        print(f"ok: {t.__name__}")
    print(f"{len(tests)} trace-RNG mirror checks passed")


if __name__ == "__main__":
    main()
