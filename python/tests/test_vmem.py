"""L1 kernel structural-quality gates (§Perf): VMEM budget + MXU
alignment for the shipped BlockSpec configuration."""

from compile.kernels.fp8_gemm import Fp8GemmConfig
from compile import vmem


def test_default_tiles_fit_vmem():
    cfg = Fp8GemmConfig()
    for m, k, n in [(64, 4096, 4096), (128, 4096, 14336),
                    (2048, 4096, 4096), (4096, 8192, 8192)]:
        e = vmem.estimate(cfg, m, k, n)
        assert e.fits, (m, k, n, e.vmem_bytes)


def test_default_tiles_are_mxu_aligned():
    # 128-multiples everywhere -> full MXU utilization on big shapes.
    cfg = Fp8GemmConfig()
    e = vmem.estimate(cfg, 4096, 4096, 4096)
    assert e.mxu_utilization == 1.0


def test_small_m_wastes_mxu_rows():
    # The §5.6 thin-GEMM effect, visible at the kernel level: M=8
    # fills 8/128 of the array rows.
    cfg = Fp8GemmConfig()
    e = vmem.estimate(cfg, 8, 1024, 1024)
    assert abs(e.mxu_utilization - 8 / 128) < 1e-9


def test_oversized_tiles_rejected():
    big = Fp8GemmConfig(bm=1024, bn=1024, bk=1024)
    e = vmem.estimate(big, 4096, 4096, 4096)
    assert not e.fits  # 1024^2 f32 accumulator alone is 4 MiB x buffers


def test_k_steps_accounting():
    cfg = Fp8GemmConfig()
    e = vmem.estimate(cfg, 256, 4096, 256)
    assert e.k_steps_per_output == 32
