"""Python mirror of the Rust KV-transfer closed forms (disaggregated
serving's migration cost path, `hwsim::interconnect::KvLink`).

Both sides compute the single-shot form

    t = context_tokens * kv_bytes_per_token / link_bw + link_lat

and the chunked-streaming schedule (`KvLink::chunked`, chunk `i`
0-based of `n`)

    t_i = bytes * (i+1) / n / link_bw + (i+1) * link_lat

with `kv_bytes_per_token = 2 * layers * kv_heads * head_dim * dtype`,
`link_bw = min(src_scale_out_bw * src_chips, dst_scale_out_bw *
dst_chips)` and `link_lat = src_lat + dst_lat`, and assert the same
pinned values (PINNED / PINNED_CHUNKED below mirror
`rust/tests/disagg_props.rs::kv_transfer_closed_form_pinned_against_python_mirror`
and `::chunked_schedule_pinned_against_python_mirror`). If either
implementation drifts, its side fails against the pins.

Stdlib-only on purpose (CI runs it without the JAX toolchain):
`python python/tests/test_kv_transfer_mirror.py`.
"""

# Scale-out NIC (bytes/s, per-hop latency s) per device — mirrors
# rust/src/hwsim/interconnect.rs.
SCALE_OUT = {
    "H100": (50.0e9, 5.0e-6),
    "A100": (25.0e9, 6.0e-6),
    "Gaudi2": (37.5e9, 6.0e-6),
    "Gaudi3": (75.0e9, 5.0e-6),
}

# (layers, kv_heads, head_dim) — mirrors rust/src/workload/llama.rs.
MODELS = {
    "llama-8b": (32, 8, 4096 // 32),
    "llama-70b": (80, 8, 8192 // 64),
}

# (model, context_tokens, src, src_chips, dst, dst_chips) -> seconds.
PINNED = [
    ("llama-8b", 2048, "H100", 1, "H100", 1, 0.005378709119999999),
    ("llama-8b", 512, "H100", 1, "Gaudi2", 1, 0.0018005697066666665),
    ("llama-70b", 4096, "H100", 4, "Gaudi2", 1, 0.03580239413333333),
    ("llama-70b", 2048, "Gaudi3", 2, "Gaudi3", 2, 0.004483924266666666),
]

# (model, context_tokens, src, src_chips, dst, dst_chips, chunks)
# -> (first-chunk seconds, last-chunk seconds).
PINNED_CHUNKED = [
    ("llama-8b", 2048, "H100", 1, "H100", 1, 4,
     0.00135217728, 0.00540870912),
    ("llama-8b", 512, "H100", 1, "Gaudi2", 1, 8,
     0.00023469621333333332, 0.0018775697066666665),
    ("llama-70b", 4096, "H100", 4, "Gaudi2", 1, 8,
     0.0044849242666666666, 0.03587939413333333),
    ("llama-70b", 2048, "Gaudi3", 2, "Gaudi3", 2, 16,
     0.0002896202666666667, 0.004633924266666667),
]


def kv_bytes_per_token(model, dtype_bytes=2.0):
    layers, kv_heads, head_dim = MODELS[model]
    return 2.0 * (layers * kv_heads * head_dim) * dtype_bytes


def kv_link(src, src_chips, dst, dst_chips):
    src_bw, src_lat = SCALE_OUT[src]
    dst_bw, dst_lat = SCALE_OUT[dst]
    return min(src_bw * src_chips, dst_bw * dst_chips), src_lat + dst_lat


def transfer_time(model, ctx, src, src_chips, dst, dst_chips):
    bw, lat = kv_link(src, src_chips, dst, dst_chips)
    bytes_ = ctx * kv_bytes_per_token(model)
    if bytes_ <= 0.0:
        return 0.0
    return bytes_ / bw + lat


def chunk_done(model, ctx, src, src_chips, dst, dst_chips, chunks, i):
    """Landing time of chunk i (0-based) of a `chunks`-way stream —
    mirrors `ChunkedTransfer::chunk_done_s` (same arithmetic order)."""
    assert 0 <= i < chunks
    bw, lat = kv_link(src, src_chips, dst, dst_chips)
    bytes_ = ctx * kv_bytes_per_token(model)
    if bytes_ <= 0.0:
        return 0.0
    return bytes_ * (i + 1) / chunks / bw + (i + 1) * lat


def test_kv_bytes_per_token_pins():
    assert kv_bytes_per_token("llama-8b") == 131072.0
    assert kv_bytes_per_token("llama-70b") == 327680.0


def test_closed_form_matches_pinned_rust_values():
    for model, ctx, src, sc, dst, dc, want in PINNED:
        got = transfer_time(model, ctx, src, sc, dst, dc)
        assert abs(got / want - 1.0) < 1e-9, (
            f"{model} ctx={ctx} {src}x{sc}->{dst}x{dc}: {got!r} != pinned {want!r}"
        )


def test_link_is_bottlenecked_and_latency_summed():
    bw, lat = kv_link("H100", 4, "Gaudi2", 1)
    assert bw == 37.5e9, "single Gaudi2 sink caps a 4-chip H100 source"
    assert lat == 5.0e-6 + 6.0e-6
    bw44, _ = kv_link("H100", 4, "Gaudi2", 4)
    assert bw44 == 150.0e9


def test_transfer_monotone_and_zero_for_nothing():
    t1 = transfer_time("llama-8b", 1024, "H100", 1, "Gaudi2", 1)
    t2 = transfer_time("llama-8b", 2048, "H100", 1, "Gaudi2", 1)
    assert t2 > t1 > 0.0
    assert transfer_time("llama-8b", 0, "H100", 1, "Gaudi2", 1) == 0.0


def test_chunked_schedule_matches_pinned_rust_values():
    for model, ctx, src, sc, dst, dc, n, first, total in PINNED_CHUNKED:
        got_first = chunk_done(model, ctx, src, sc, dst, dc, n, 0)
        got_total = chunk_done(model, ctx, src, sc, dst, dc, n, n - 1)
        assert abs(got_first / first - 1.0) < 1e-9, (
            f"{model} ctx={ctx} x{n}: first {got_first!r} != pinned {first!r}"
        )
        assert abs(got_total / total - 1.0) < 1e-9, (
            f"{model} ctx={ctx} x{n}: total {got_total!r} != pinned {total!r}"
        )


def test_chunked_limits_and_monotonicity():
    args = ("llama-8b", 2048, "H100", 1, "Gaudi2", 1)
    single = transfer_time(*args)
    # One chunk reproduces the single-shot closed form bit-exactly.
    assert chunk_done(*args, 1, 0) == single
    # Chunks land in order; the first chunk strictly beats single-shot;
    # the total stream time is monotone non-decreasing in chunk count
    # and never beats the wire.
    prev_total, prev_first = 0.0, float("inf")
    for n in range(1, 33):
        first = chunk_done(*args, n, 0)
        total = chunk_done(*args, n, n - 1)
        assert first <= prev_first and total >= prev_total
        assert single <= total and (n == 1 or first < single)
        for i in range(1, n):
            assert chunk_done(*args, n, i) > chunk_done(*args, n, i - 1)
        prev_total, prev_first = total, first
    # Zero bytes land instantly however finely chunked.
    assert chunk_done("llama-8b", 0, "H100", 1, "Gaudi2", 1, 8, 7) == 0.0


def main():
    tests = [
        test_kv_bytes_per_token_pins,
        test_closed_form_matches_pinned_rust_values,
        test_link_is_bottlenecked_and_latency_summed,
        test_transfer_monotone_and_zero_for_nothing,
        test_chunked_schedule_matches_pinned_rust_values,
        test_chunked_limits_and_monotonicity,
    ]
    for t in tests:
        t()
        print(f"ok: {t.__name__}")
    print(f"{len(tests)} KV-transfer mirror checks passed")


if __name__ == "__main__":
    main()
