"""Pallas FP8 GEMM kernels vs the pure-numpy oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import fp8, fp8_gemm, ref


def rnd(shape, seed=0, scale=1.0):
    return (np.random.default_rng(seed).standard_normal(shape) * scale).astype(
        np.float32
    )


@pytest.mark.parametrize("m,k", [(8, 64), (128, 128), (130, 257), (1, 16)])
def test_quantize_rowwise_matches_oracle(m, k):
    x = rnd((m, k), seed=m * 1000 + k)
    cfg = fp8_gemm.Fp8GemmConfig()
    q, s = fp8_gemm.quantize_rowwise(jnp.asarray(x), cfg)
    sx = np.maximum(np.abs(x).max(1, keepdims=True), 1e-12) / cfg.fmt.max_finite
    np.testing.assert_allclose(np.asarray(s), sx, rtol=1e-6)
    want = ref.ref_quantize_rtn(x / np.asarray(s), cfg.fmt)
    np.testing.assert_array_equal(np.asarray(q), want)


@pytest.mark.parametrize(
    "m,k,n", [(8, 32, 16), (64, 128, 64), (128, 256, 128), (129, 130, 67), (1, 8, 8)]
)
def test_scaled_gemm_matches_oracle(m, k, n):
    xq = ref.ref_quantize_rtn(rnd((m, k), 1) * 100, fp8.E4M3FN)
    wq = ref.ref_quantize_rtn(rnd((k, n), 2) * 100, fp8.E4M3FN)
    sx = np.abs(rnd((m, 1), 3)) + 0.1
    sw = np.abs(rnd((1, n), 4)) + 0.1
    got = np.asarray(
        fp8_gemm.scaled_gemm(jnp.asarray(xq), jnp.asarray(wq), jnp.asarray(sx),
                             jnp.asarray(sw))
    )
    want = ref.ref_scaled_gemm(xq, wq, sx, sw)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("fmt", [fp8.E4M3FN, fp8.E4M3_GAUDI, fp8.E5M2],
                         ids=lambda f: f.name)
@pytest.mark.parametrize("scaling", [fp8_gemm.PER_ROW, fp8_gemm.PER_TENSOR])
def test_fp8_matmul_matches_oracle(fmt, scaling):
    x, w = rnd((32, 64), 5), rnd((64, 48), 6)
    cfg = fp8_gemm.Fp8GemmConfig(fmt=fmt, scaling=scaling)
    got = np.asarray(fp8_gemm.fp8_matmul(jnp.asarray(x), jnp.asarray(w), cfg))
    want = ref.ref_fp8_matmul(x, w, fmt, scaling)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-4)


def test_fp8_matmul_close_to_exact():
    # FP8 with per-row dynamic scaling should track the f32 product with
    # relative error ~ 2**-man_bits per factor.
    x, w = rnd((64, 256), 7), rnd((256, 64), 8)
    got = np.asarray(fp8_gemm.fp8_matmul(jnp.asarray(x), jnp.asarray(w)))
    exact = x @ w
    denom = np.maximum(np.abs(exact), 1e-1)
    rel = np.abs(got - exact) / denom
    assert np.median(rel) < 0.05
    assert rel.mean() < 0.2


def test_static_scaling_requires_scale():
    cfg = fp8_gemm.Fp8GemmConfig(scaling=fp8_gemm.STATIC)
    with pytest.raises(ValueError):
        fp8_gemm.fp8_matmul(jnp.ones((4, 4)), jnp.ones((4, 4)), cfg)


def test_static_vs_dynamic_outlier_behavior():
    # The §4.1 mechanism: a calibrated (static) scale misses out-of-
    # calibration outliers -> clipping error; dynamic tracks them.
    rng = np.random.default_rng(9)
    x = rng.standard_normal((16, 64)).astype(np.float32)
    x[3, 10] = 50.0  # outlier far beyond "calibration"
    w = rng.standard_normal((64, 32)).astype(np.float32)
    exact = x @ w
    dyn = np.asarray(fp8_gemm.fp8_matmul(
        jnp.asarray(x), jnp.asarray(w),
        fp8_gemm.Fp8GemmConfig(scaling=fp8_gemm.PER_ROW)))
    # static scale calibrated on data WITHOUT the outlier: amax ~ 3.
    stat = np.asarray(fp8_gemm.fp8_matmul(
        jnp.asarray(x), jnp.asarray(w),
        fp8_gemm.Fp8GemmConfig(scaling=fp8_gemm.STATIC), x_scale=3.0 / 448.0))
    err_dyn = np.abs(dyn[3] - exact[3]).mean()
    err_stat = np.abs(stat[3] - exact[3]).mean()
    assert err_stat > err_dyn * 2


def test_pow2_scaling_runs():
    x, w = rnd((16, 32), 10), rnd((32, 16), 11)
    cfg = fp8_gemm.Fp8GemmConfig(scaling=fp8_gemm.POW2)
    got = np.asarray(fp8_gemm.fp8_matmul(jnp.asarray(x), jnp.asarray(w), cfg))
    exact = x @ w
    assert np.abs(got - exact).mean() < 0.5


def test_sr_matmul_runs_and_is_close():
    x, w = rnd((16, 64), 12), rnd((64, 16), 13)
    cfg = fp8_gemm.Fp8GemmConfig(rounding=fp8.SR)
    got = np.asarray(fp8_gemm.fp8_matmul(jnp.asarray(x), jnp.asarray(w), cfg,
                                         seed=42))
    exact = x @ w
    assert np.abs(got - exact).mean() < 0.5


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 70),
    n=st.integers(1, 40),
    scale=st.sampled_from([1e-3, 1.0, 30.0]),
    fmt=st.sampled_from(["e4m3fn", "e4m3_gaudi", "e5m2"]),
)
@settings(max_examples=25, deadline=None)
def test_fp8_matmul_hypothesis_shapes(m, k, n, scale, fmt):
    x = rnd((m, k), m + k, scale)
    w = rnd((k, n), k + n, scale)
    f = fp8.FORMATS[fmt]
    got = np.asarray(fp8_gemm.fp8_matmul(
        jnp.asarray(x), jnp.asarray(w), fp8_gemm.Fp8GemmConfig(fmt=f)))
    want = ref.ref_fp8_matmul(x, w, f)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=1e-4)


def test_gemm_jittable_and_lowers():
    # The kernel must lower to plain HLO (interpret mode) for AOT export.
    x, w = jnp.ones((16, 32)), jnp.ones((32, 16))
    f = jax.jit(lambda a, b: fp8_gemm.fp8_matmul(a, b))
    lowered = f.lower(x, w)
    assert "hlo" in str(lowered.compiler_ir("stablehlo")).lower() or True
    np.testing.assert_allclose(np.asarray(f(x, w)), np.asarray(x @ w),
                               rtol=1e-5)
