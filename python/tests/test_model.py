"""L2 model tests: prefill/decode consistency, precision plumbing,
training smoke, calibration."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M
from compile import train as T
from compile.kernels import fp8, fp8_gemm


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(M.TIERS["1b"], max_seq=32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_param_count_matches_init(setup):
    cfg, params = setup
    total = sum(np.asarray(x).size for x in jax.tree.leaves(params))
    assert total == cfg.param_count()


def test_decode_matches_prefill_next_token(setup):
    """Teacher-forced decode over a prompt must reproduce prefill's
    logits at every position (the KV-cache correctness invariant)."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab, (2, 10))
    lengths = jnp.asarray([10, 10], jnp.int32)
    logits_pre, _, _ = M.prefill(params, cfg, M.BF16, jnp.asarray(tokens), lengths)

    # Rebuild the same sequence token by token through decode_step.
    first = tokens[:, :1]
    l1 = jnp.asarray([1, 1], jnp.int32)
    logits_0, kc, vc = M.prefill(params, cfg, M.BF16, jnp.asarray(first), l1)
    np.testing.assert_allclose(
        np.asarray(logits_0[:, 0]), np.asarray(logits_pre[:, 0]),
        rtol=2e-4, atol=2e-4)

    cur_len = l1
    for t in range(1, 10):
        tok = jnp.asarray(tokens[:, t])
        logits_t, kc, vc = M.decode_step(params, cfg, M.BF16, tok, cur_len, kc, vc)
        np.testing.assert_allclose(
            np.asarray(logits_t), np.asarray(logits_pre[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"position {t}")
        cur_len = cur_len + 1


def test_fp8_decode_close_to_bf16(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    tokens = rng.integers(0, cfg.vocab, (2, 8))
    lengths = jnp.asarray([8, 8], jnp.int32)
    _, kc, vc = M.prefill(params, cfg, M.BF16, jnp.asarray(tokens), lengths)
    tok = jnp.asarray([3, 5])
    l_bf, _, _ = M.decode_step(params, cfg, M.BF16, tok, lengths, kc, vc)
    l_f8, _, _ = M.decode_step(params, cfg, M.FP8_DYNAMIC, tok, lengths, kc, vc)
    # FP8 linears perturb logits slightly but not wildly.
    diff = np.abs(np.asarray(l_bf) - np.asarray(l_f8))
    assert diff.max() < 0.3, diff.max()
    # and the top-1 token usually agrees
    agree = (np.argmax(np.asarray(l_bf), -1) == np.argmax(np.asarray(l_f8), -1)).mean()
    assert agree >= 0.5


def test_variable_lengths_masked(setup):
    """Padding tokens beyond `lengths` must not change logits of the
    valid prefix."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    tokens = rng.integers(0, cfg.vocab, (1, 12))
    lengths = jnp.asarray([6], jnp.int32)
    la, _, _ = M.prefill(params, cfg, M.BF16, jnp.asarray(tokens), lengths)
    tokens2 = tokens.copy()
    tokens2[0, 6:] = (tokens2[0, 6:] + 17) % cfg.vocab  # scramble padding
    lb, _, _ = M.prefill(params, cfg, M.BF16, jnp.asarray(tokens2), lengths)
    np.testing.assert_allclose(
        np.asarray(la[0, :6]), np.asarray(lb[0, :6]), rtol=1e-5, atol=1e-5)


def test_static_scales_calibration(setup):
    cfg, params = setup
    calib = jnp.asarray(np.random.default_rng(3).integers(0, cfg.vocab, (2, 8)))
    scales = M.calibrate_static_scales(params, cfg, calib, fp8.E4M3FN)
    # One scale per linear per layer.
    assert len(scales) == cfg.layers * 7
    assert all(v > 0 for v in scales.values())
    # Static precision uses them without error.
    prec = M.PrecisionConfig(mode="fp8", scaling=fp8_gemm.STATIC,
                             static_scales=scales)
    lengths = jnp.asarray([8, 8], jnp.int32)
    logits, _, _ = M.prefill(params, cfg, prec, calib, lengths)
    assert np.isfinite(np.asarray(logits)).all()


def test_training_reduces_loss():
    # The 1b tier is deliberately under-parameterized for the
    # second-order synthetic language (that is what gives Table 5 its
    # model-size axis), so short-run loss moves slowly but must move.
    params, cfg, history = T.train_tier("1b", steps=150, quiet=True)
    first = history[0][1]
    last = history[-1][1]
    assert last < first - 0.1, f"loss {first} -> {last}"


def test_save_load_roundtrip(tmp_path):
    cfg = M.TIERS["1b"]
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    path = str(tmp_path / "p.npz")
    T.save_params(params, path)
    loaded = T.load_params(path)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_synthetic_language_is_learnable_structure():
    lang = T.SyntheticLanguage(seed=0)
    rng = np.random.default_rng(0)
    batch = lang.batch(rng, 8, 64)
    assert batch.shape == (8, 64)
    assert batch.min() >= 0 and batch.max() < T.VOCAB
    # The copy pattern: after COPY_TOKEN at i, out[i+1] == out[i+1-delta].
    hits = total = 0
    for row in batch:
        for i in range(T.COPY_DELTA, 63):
            if row[i] == T.COPY_TOKEN:
                total += 1
                hits += row[i + 1] == row[i + 1 - T.COPY_DELTA]
    # A COPY_TOKEN can itself be *copied* into the stream (source was a
    # copy marker), in which case it is a literal token, not a marker —
    # so the invariant holds for the vast majority, not all.
    if total:
        assert hits >= total * 0.85, (hits, total)


def test_sequence_logprob_prefers_true_continuation():
    # On a trained model the generator's own continuation should score
    # higher than random tokens most of the time.
    params, cfg, _ = T.train_tier("1b", steps=150, quiet=True)
    lang = T.SyntheticLanguage(seed=0)
    rng = np.random.default_rng(11)
    wins = 0
    n = 12
    for _ in range(n):
        seq = lang.sample(rng, 48)
        fake = seq.copy()
        fake[24:] = rng.integers(0, T.VOCAB, 24)
        both = jnp.asarray(np.stack([seq, fake]))
        lp = M.sequence_logprob(params, cfg, M.BF16, both, prefix_len=24)
        wins += bool(lp[0] > lp[1])
    assert wins >= n * 2 // 3, f"{wins}/{n}"
