"""Pallas decode-attention kernel vs the numpy oracle."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref


def mk(b, h, hkv, d, s, seed=0):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((b, h, d)).astype(np.float32)
    k = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    v = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    lengths = rng.integers(1, s + 1, size=b).astype(np.int32)
    return q, k, v, lengths


@pytest.mark.parametrize("b,h,hkv,d,s", [
    (1, 4, 4, 16, 8),     # MHA
    (2, 8, 2, 32, 64),    # GQA g=4
    (4, 8, 1, 16, 33),    # MQA, odd seq
])
def test_decode_attention_matches_oracle(b, h, hkv, d, s):
    q, k, v, lengths = mk(b, h, hkv, d, s, seed=b * 100 + s)
    got = np.asarray(attention.decode_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(lengths)))
    want = ref.ref_decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_masking_ignores_stale_cache():
    # Entries beyond `lengths` must not affect the output.
    q, k, v, _ = mk(2, 4, 2, 16, 32, seed=3)
    lengths = np.asarray([5, 20], np.int32)
    out1 = np.asarray(attention.decode_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(lengths)))
    k2, v2 = k.copy(), v.copy()
    k2[0, 5:] = 1e6  # garbage past the valid prefix
    v2[1, 20:] = -1e6
    out2 = np.asarray(attention.decode_attention(
        jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), jnp.asarray(lengths)))
    np.testing.assert_allclose(out1, out2, rtol=1e-6)


def test_length_one_attends_single_position():
    q, k, v, _ = mk(1, 2, 2, 8, 16, seed=4)
    lengths = np.asarray([1], np.int32)
    got = np.asarray(attention.decode_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(lengths)))
    # softmax over a single position == that position's V.
    np.testing.assert_allclose(got[0, 0], v[0, 0, 0], rtol=1e-6)


@given(b=st.integers(1, 4), g=st.integers(1, 4), hkv=st.integers(1, 4),
       d=st.sampled_from([8, 16]), s=st.integers(2, 40))
@settings(max_examples=20, deadline=None)
def test_decode_attention_hypothesis(b, g, hkv, d, s):
    h = g * hkv
    q, k, v, lengths = mk(b, h, hkv, d, s, seed=b + g + s)
    got = np.asarray(attention.decode_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(lengths)))
    want = ref.ref_decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
