"""FP8 emulation vs the enumerated-lattice oracle (bit-exact)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import fp8, ref

FORMATS = [fp8.E4M3FN, fp8.E4M3_GAUDI, fp8.E5M2]
IDS = [f.name for f in FORMATS]


@pytest.mark.parametrize("fmt", FORMATS, ids=IDS)
def test_lattice_counts(fmt):
    lat = ref.lattice(fmt.name)
    # E4M3FN: 2 sign * (7 subnormal + 15 binades * 8 - 1 NaN-slot) ... we
    # only check the salient facts asserted in the paper §3.2.
    assert lat[-1] == fmt.max_finite
    assert lat[1] == fmt.min_subnormal
    if fmt is fp8.E4M3_GAUDI:
        # "seven fewer magnitude representations" than NVIDIA E4M3FN.
        assert len(ref.lattice("e4m3fn")) - len(lat) == 7


@pytest.mark.parametrize("fmt", FORMATS, ids=IDS)
def test_quantize_matches_oracle_dense_sweep(fmt):
    # Dense sweep over the format's dynamic range, both signs, plus
    # exact lattice points and midpoints (the tie-break cases).
    lat = ref.lattice(fmt.name)
    mids = (lat[1:] + lat[:-1]) / 2.0
    xs = np.concatenate([
        np.linspace(-fmt.max_finite * 1.5, fmt.max_finite * 1.5, 20001),
        lat, -lat, mids, -mids,
        np.array([0.0, -0.0, fmt.min_subnormal / 2, -fmt.min_subnormal / 2]),
    ]).astype(np.float32)
    got = np.asarray(fp8.quantize(jnp.asarray(xs), fmt, fp8.RTN))
    want = ref.ref_quantize_rtn(xs, fmt)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("fmt", FORMATS, ids=IDS)
def test_quantize_idempotent(fmt):
    lat = ref.lattice(fmt.name)
    xs = np.concatenate([lat, -lat]).astype(np.float32)
    got = np.asarray(fp8.quantize(jnp.asarray(xs), fmt, fp8.RTN))
    np.testing.assert_array_equal(got, xs)


@pytest.mark.parametrize("fmt", FORMATS, ids=IDS)
def test_stochastic_rounding_is_unbiased_and_on_lattice(fmt):
    key = jax.random.PRNGKey(0)
    x = jnp.full((20000,), 1.0 + 2.0 ** (-fmt.man_bits) * 0.3)  # 30% up
    got = np.asarray(fp8.quantize(x, fmt, fp8.SR, key))
    lat = ref.lattice(fmt.name)
    assert np.isin(got, lat).all()
    lo = 1.0
    hi = 1.0 + 2.0 ** (-fmt.man_bits)
    p_up = (got == hi).mean()
    assert set(np.unique(got)) <= {lo, hi}
    assert abs(p_up - 0.3) < 0.02  # Eq. 2: E[q] == x


def test_e5m2_matches_float16_truncation():
    # Independent cross-check: E5M2 has float16's exponent range, so
    # RTN-to-E5M2 == RTN of f32 to f16 with mantissa re-rounded to 2 bits.
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(50000) * rng.choice([1e-4, 1e-2, 1.0, 100.0],
                                                 50000)).astype(np.float32)
    x = np.clip(x, -fp8.E5M2.max_finite, fp8.E5M2.max_finite)
    got = np.asarray(fp8.quantize(jnp.asarray(x), fp8.E5M2, fp8.RTN))
    want = ref.ref_quantize_rtn(x, fp8.E5M2)
    np.testing.assert_array_equal(got, want)


@given(st.lists(st.floats(-500.0, 500.0, allow_nan=False, width=32),
                min_size=1, max_size=64))
@settings(max_examples=200, deadline=None)
def test_quantize_hypothesis_e4m3fn(xs):
    x = np.asarray(xs, np.float32)
    got = np.asarray(fp8.quantize(jnp.asarray(x), fp8.E4M3FN, fp8.RTN))
    want = ref.ref_quantize_rtn(x, fp8.E4M3FN)
    np.testing.assert_array_equal(got, want)


@given(st.floats(1e-6, 6e4, allow_nan=False),
       st.sampled_from(["e4m3fn", "e4m3_gaudi", "e5m2"]))
@settings(max_examples=300, deadline=None)
def test_quantize_error_bound(x, fmt_name):
    # |q(x) - x| <= quantum/2 for in-range values (classic RTN bound).
    fmt = fp8.FORMATS[fmt_name]
    if x > fmt.max_finite:
        return
    q = float(fp8.quantize(jnp.asarray([x], jnp.float32), fmt, fp8.RTN)[0])
    lat = ref.lattice(fmt_name)
    i = np.searchsorted(lat, x)
    spacing = lat[min(i, len(lat) - 1)] - lat[max(i - 1, 0)]
    assert abs(q - x) <= spacing / 2 + 1e-30


def test_scaling_helpers():
    x = jnp.asarray(np.random.default_rng(1).standard_normal((16, 32)),
                    jnp.float32)
    rs = fp8.row_scales(x, fp8.E4M3FN)
    assert rs.shape == (16, 1)
    np.testing.assert_allclose(
        np.asarray(rs[:, 0]),
        np.abs(np.asarray(x)).max(1) / 448.0, rtol=1e-6)
    ts = fp8.tensor_scale(x, fp8.E4M3FN)
    assert float(ts) == pytest.approx(float(np.abs(np.asarray(x)).max()) / 448.0)


def test_pow2_scale_snapping():
    assert float(fp8.pow2_scale(jnp.float32(0.3))) == 0.5
    assert float(fp8.pow2_scale(jnp.float32(0.5))) == 0.5
    # Gaudi-2 fixed set snaps UP to the next member.
    s = fp8.pow2_scale(jnp.float32(0.01), fp8.GAUDI2_HW_SCALES)
    assert float(s) == 2.0**-4
    s = fp8.pow2_scale(jnp.float32(3.0), fp8.GAUDI2_HW_SCALES)
    assert float(s) == 2.0**4
    # Above the largest member: clamp to largest.
    s = fp8.pow2_scale(jnp.float32(100.0), fp8.GAUDI2_HW_SCALES)
    assert float(s) == 2.0**4
