"""Build-time training of the tiny Llama tiers on a synthetic corpus.

Substitute for the paper's Llama v3.x checkpoints (DESIGN.md): we cannot
load 1B-70B weights, so we train four width-tiers of the same
architecture on a synthetic language with learnable structure, then run
the paper's PTQ experiments (Tables 4-5) against them.

The synthetic language mixes:
  * a fixed sparse second-order Markov chain (local structure; small
    models can learn it), and
  * long-range copy patterns (a token announces that the token k steps
    back repeats; larger models learn it better),
so accuracy improves monotonically with tier size — giving Table 5's
model-size axis meaning.

Usage:  python -m compile.train --tier 8b --steps 400 --out ../artifacts/ckpt
"""

from __future__ import annotations

import argparse
import json
import os
import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from . import model as M

VOCAB = 256
COPY_TOKEN = 255          # "repeat the token from DELTA steps back"
COPY_DELTA = 8
COPY_PROB = 0.08
BRANCH = 4                # plausible continuations per bigram state


class SyntheticLanguage:
    """Deterministic synthetic corpus generator (seeded)."""

    def __init__(self, seed: int = 0):
        rng = np.random.default_rng(seed)
        # Sparse second-order transitions: state (a, b) -> BRANCH tokens
        # with Zipf-ish probabilities.
        self.succ = rng.integers(0, VOCAB - 1, size=(VOCAB, VOCAB, BRANCH))
        p = 1.0 / np.arange(1, BRANCH + 1)
        self.probs = p / p.sum()

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int64)
        out[0] = rng.integers(0, VOCAB - 1)
        out[1] = rng.integers(0, VOCAB - 1)
        i = 2
        while i < length:
            if i >= COPY_DELTA and rng.random() < COPY_PROB and i + 1 < length:
                out[i] = COPY_TOKEN
                out[i + 1] = out[i + 1 - COPY_DELTA]
                i += 2
                continue
            a, b = out[i - 2], out[i - 1]
            choice = rng.choice(BRANCH, p=self.probs)
            out[i] = self.succ[a, b, choice]
            i += 1
        return out

    def batch(self, rng: np.random.Generator, b: int, s: int) -> np.ndarray:
        return np.stack([self.sample(rng, s) for _ in range(b)])


# ---------------------------------------------------------------------------
# Hand-rolled Adam (optax is not available in this image)
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new = jax.tree.map(
        lambda p, mi, vi: p - lr * (mi * mhat_scale)
        / (jnp.sqrt(vi * vhat_scale) + eps),
        params, m, v)
    return new, {"m": m, "v": v, "t": t}


def train_tier(tier: str, steps: int, seed: int = 0, batch: int = 32,
               seq: int = 64, lr: float = 1e-3, log_every: int = 50,
               quiet: bool = False):
    cfg = M.TIERS[tier]
    lang = SyntheticLanguage(seed=0)  # language fixed across tiers
    rng = np.random.default_rng(seed + 1)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    loss_fn = jax.jit(partial(M.lm_loss, cfg=cfg, prec=M.BF16))

    @jax.jit
    def step_fn(params, opt, tokens, lr_t):
        loss, grads = jax.value_and_grad(
            lambda p: M.lm_loss(p, cfg, M.BF16, tokens))(params)
        params, opt = adam_update(params, grads, opt, lr_t)
        return params, opt, loss

    history = []
    t0 = time.time()
    for it in range(steps):
        tokens = jnp.asarray(lang.batch(rng, batch, seq))
        # cosine decay with short warmup
        warm = min(1.0, (it + 1) / 20)
        lr_t = lr * warm * 0.5 * (1 + np.cos(np.pi * it / max(steps, 1)))
        params, opt, loss = step_fn(params, opt, tokens, lr_t)
        if it % log_every == 0 or it == steps - 1:
            history.append((it, float(loss)))
            if not quiet:
                print(f"[{tier}] step {it:4d} loss {float(loss):.4f} "
                      f"({time.time()-t0:.1f}s)")
    return params, cfg, history


def save_params(params, path: str):
    flat = {}

    def flatten(prefix, tree):
        if isinstance(tree, dict):
            for k, v in tree.items():
                flatten(f"{prefix}/{k}" if prefix else k, v)
        elif isinstance(tree, list):
            for i, v in enumerate(tree):
                flatten(f"{prefix}/{i}", v)
        else:
            flat[prefix] = np.asarray(tree)

    flatten("", params)
    np.savez(path, **flat)


def load_params(path: str):
    """Inverse of ``save_params``: rebuild the nested dict/list pytree."""
    data = np.load(path)
    root: dict = {}
    for key in data.files:
        parts = key.split("/")
        node = root
        for i, part in enumerate(parts[:-1]):
            nxt_container = [] if parts[i + 1].isdigit() else {}
            if isinstance(node, list):
                idx = int(part)
                while len(node) <= idx:
                    node.append([] if parts[i + 1].isdigit() else {})
                node = node[idx]
            else:
                if part not in node:
                    node[part] = nxt_container
                node = node[part]
        last = parts[-1]
        val = jnp.asarray(data[key])
        if isinstance(node, list):
            idx = int(last)
            while len(node) <= idx:
                node.append(None)
            node[idx] = val
        else:
            node[last] = val
    return root


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tier", default="1b", choices=list(M.TIERS))
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--out", default="../artifacts/ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    params, cfg, history = train_tier(args.tier, args.steps, args.seed)
    path = os.path.join(args.out, f"{args.tier}.npz")
    save_params(params, path)
    with open(os.path.join(args.out, f"{args.tier}.history.json"), "w") as f:
        json.dump({"tier": args.tier, "loss": history,
                   "params": cfg.param_count()}, f)
    print(f"saved {path} ({cfg.param_count():,} params)")


if __name__ == "__main__":
    main()
