"""L1 Pallas kernels: FP8 quantization + scaled GEMM.

TPU-shaped (paper's CUDA/Synapse kernels re-thought per the
Hardware-Adaptation note in DESIGN.md):

  * tiles are (bm, bk) x (bk, bn) with 128-multiples so the MXU systolic
    array is fed full 128x128 panels;
  * accumulation is a float32 VMEM scratch, written back once on the last
    K-step (output-stationary — same dataflow as Gaudi's MME, and the
    natural MXU schedule);
  * dequantization (the row/tensor scale outer product) is fused into the
    epilogue of the last K-step instead of a separate pass over HBM —
    the TPU analogue of the fused scaling-factor application the paper
    credits for Gaudi's hardware-accelerated scaling path.

``interpret=True`` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; numerics are identical.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fp8

# Scaling strategies (paper §4.1 / Table 2-3 column headers).
PER_ROW = "per_row"      # dynamic, one scale per token/row
PER_TENSOR = "per_tensor"  # dynamic, one scale per tensor
STATIC = "static"        # calibrated scale supplied by caller
POW2 = "pow2"            # per-tensor snapped to hw power-of-2 set


@dataclasses.dataclass(frozen=True)
class Fp8GemmConfig:
    """Configuration of one FP8 GEMM — format x rounding x scaling."""

    fmt: fp8.Fp8Format = fp8.E4M3FN
    rounding: str = fp8.RTN
    scaling: str = PER_ROW
    # Tile sizes; shapes smaller than a tile fall back to one block.
    bm: int = 128
    bn: int = 128
    bk: int = 128


def _block(dim: int, b: int) -> int:
    return min(dim, b)


def _pad_to(x: jnp.ndarray, mult: tuple[int, ...]) -> jnp.ndarray:
    """Zero-pad each dim of x up to a multiple of mult (interpret-mode
    pallas fills out-of-bounds block slack with NaN, so we pad
    explicitly and slice the result back)."""
    pads = []
    for d, m in zip(x.shape, mult):
        pads.append((0, (-d) % m))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# Quantization kernel: per-row dynamic scaling fused with rounding.
# ---------------------------------------------------------------------------


def _quant_rowwise_kernel(x_ref, q_ref, s_ref, *, fmt: fp8.Fp8Format,
                          rounding: str, seed: int):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / fmt.max_finite
    scaled = x / scale
    q = _round_on_lattice(scaled, fmt, rounding, seed, pl.program_id(0))
    q_ref[...] = q
    s_ref[...] = scale


def _round_on_lattice(scaled, fmt, rounding, seed, block_id):
    """Shared rounding body (RTN / SR) on pre-scaled values."""
    quantum = _quantum(fmt, scaled)
    t = scaled / quantum
    if rounding == fp8.RTN:
        r = jnp.round(t)
    else:  # stochastic rounding, paper Eq. 2
        key = jax.random.fold_in(jax.random.PRNGKey(seed), block_id)
        lo = jnp.floor(t)
        u = jax.random.uniform(key, t.shape, dtype=jnp.float32)
        r = lo + (u < (t - lo)).astype(jnp.float32)
    y = r * quantum
    return jnp.clip(y, -fmt.max_finite, fmt.max_finite)


def _quantum(fmt, x):
    ax = jnp.abs(x)
    e = jnp.floor(jnp.log2(jnp.maximum(ax, 1e-45)))
    e = jnp.clip(e, fmt.emin, None)
    # ldexp, not exp2: exp2 is a polynomial approximation (inexact).
    return jnp.ldexp(jnp.float32(1.0), (e - fmt.man_bits).astype(jnp.int32))


def quantize_rowwise(x: jnp.ndarray, cfg: Fp8GemmConfig, seed: int = 0):
    """Pallas row-wise dynamic quantization.

    Returns (q, scales) with q on the FP8 lattice (stored f32) and
    scales of shape (M, 1).
    """
    m0 = x.shape[0]
    bm = _block(m0, cfg.bm)
    x = _pad_to(x.astype(jnp.float32), (bm, 1))
    m, k = x.shape
    grid = (pl.cdiv(m, bm),)
    kern = functools.partial(_quant_rowwise_kernel, fmt=cfg.fmt,
                             rounding=cfg.rounding, seed=seed)
    q, s = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=True,
    )(x)
    return q[:m0], s[:m0]


# ---------------------------------------------------------------------------
# Scaled GEMM kernel: f32 VMEM accumulator, fused dequant epilogue.
# ---------------------------------------------------------------------------


def _gemm_kernel(xq_ref, wq_ref, sx_ref, sw_ref, o_ref, *, nk: int):
    # Output-stationary accumulation: the (bm, bn) output block stays
    # resident (VMEM under real lowering) across all K-steps — the same
    # dataflow as Gaudi's MME and the natural MXU schedule.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        xq_ref[...], wq_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        # Fused dequantization: out = acc * sx (per row) * sw (per col /
        # tensor). sx is (bm, 1), sw is (1, bn); both broadcast.
        o_ref[...] = o_ref[...] * sx_ref[...] * sw_ref[...]


def scaled_gemm(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    sx: jnp.ndarray,
    sw: jnp.ndarray,
    cfg: Fp8GemmConfig | None = None,
) -> jnp.ndarray:
    """(M,K)x(K,N) GEMM over FP8-lattice inputs with fused dequant.

    ``sx``: (M, 1) row scales of x; ``sw``: (1, N) column scales of w
    (a per-tensor scale is passed broadcast to (1, N)).
    """
    cfg = cfg or Fp8GemmConfig()
    m0, k0 = xq.shape
    k2, n0 = wq.shape
    assert k0 == k2, (k0, k2)
    bm, bn, bk = _block(m0, cfg.bm), _block(n0, cfg.bn), _block(k0, cfg.bk)
    xq = _pad_to(xq, (bm, bk))
    wq = _pad_to(wq, (bk, bn))
    sx = _pad_to(sx, (bm, 1))
    sw = jnp.broadcast_to(sw, (1, n0))
    sw = _pad_to(sw, (1, bn))
    m, k = xq.shape
    n = wq.shape[1]
    nk = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), nk)
    kern = functools.partial(_gemm_kernel, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(xq, wq, sx, sw)
    return out[:m0, :n0]


def fp8_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    cfg: Fp8GemmConfig | None = None,
    w_scale: jnp.ndarray | None = None,
    x_scale: jnp.ndarray | None = None,
    seed: int = 0,
) -> jnp.ndarray:
    """End-to-end FP8 matmul: quantize x and w per cfg, GEMM, dequant.

    Weights use dynamic per-column (per-output-channel) scaling unless a
    static ``w_scale`` is given; activations follow ``cfg.scaling``.
    """
    cfg = cfg or Fp8GemmConfig()
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)

    # --- weights: per-column amax (transpose-row) or static scale.
    if w_scale is None:
        w_amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)  # (1, N)
        sw = jnp.maximum(w_amax, 1e-12) / cfg.fmt.max_finite
    else:
        sw = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (1, w.shape[1]))
    wq = fp8.quantize(w / sw, cfg.fmt, fp8.RTN)

    # --- activations per scaling strategy.
    if cfg.scaling == PER_ROW:
        xq, sx = quantize_rowwise(x, cfg, seed)
    else:
        if cfg.scaling == PER_TENSOR:
            s = fp8.tensor_scale(x, cfg.fmt)
        elif cfg.scaling == POW2:
            s = fp8.pow2_scale(fp8.tensor_scale(x, cfg.fmt), fp8.GAUDI2_HW_SCALES)
        elif cfg.scaling == STATIC:
            if x_scale is None:
                raise ValueError("static scaling requires x_scale")
            s = jnp.asarray(x_scale, jnp.float32)
        else:
            raise ValueError(f"unknown scaling {cfg.scaling!r}")
        key = jax.random.PRNGKey(seed) if cfg.rounding == fp8.SR else None
        xq = fp8.quantize(x / s, cfg.fmt, cfg.rounding, key)
        sx = jnp.broadcast_to(jnp.asarray(s, jnp.float32), (x.shape[0], 1))

    return scaled_gemm(xq, wq, sx, sw, cfg)
