"""Bit-exact software emulation of FP8 formats (build-time only).

The paper (§3.2) distinguishes three FP8 lattices relevant to the two
devices under study:

  * ``E4M3FN``     — NVIDIA's E4M3 variant: no infinities, a single NaN
                     bit-pattern, max finite value 448 (exp field 15 is a
                     normal binade except mantissa 111).
  * ``E4M3_GAUDI`` — Gaudi 2's IEEE-style E4M3: exponent field 15 reserved
                     for inf/NaN, so max finite value is 240 ("seven fewer
                     magnitude representations", paper §3.2 E4M3-range).
  * ``E5M2``       — IEEE-style E5M2, max finite 57344.

All quantizers here SATURATE on overflow (matching the saturating casts
used by both vendors' inference stacks) and support round-to-nearest-even
(RTN) and stochastic rounding (SR, Eq. 2 of the paper).

Values are *represented* as float32 restricted to the target lattice —
the standard software-emulation trick — so they can flow through jnp /
Pallas math unchanged while being numerically identical to hardware FP8.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Fp8Format:
    """Parameters of an FP8 value lattice.

    ``emin`` is the exponent of the smallest *normal* binade;
    subnormals extend down to ``2**(emin - man_bits)``.
    """

    name: str
    exp_bits: int
    man_bits: int
    max_finite: float
    emin: int

    @property
    def min_subnormal(self) -> float:
        return 2.0 ** (self.emin - self.man_bits)


# NVIDIA E4M3 (FN): bias 7, top binade keeps 7 of 8 mantissa codes.
E4M3FN = Fp8Format("e4m3fn", 4, 3, 448.0, -6)
# Gaudi-2 E4M3: IEEE reservation of exponent 15 -> max 1.875 * 2**7 = 240.
E4M3_GAUDI = Fp8Format("e4m3_gaudi", 4, 3, 240.0, -6)
# IEEE E5M2: bias 15, max 1.75 * 2**15.
E5M2 = Fp8Format("e5m2", 5, 2, 57344.0, -14)

FORMATS = {f.name: f for f in (E4M3FN, E4M3_GAUDI, E5M2)}

RTN = "rtn"
SR = "sr"


def _quantum(fmt: Fp8Format, x: jnp.ndarray) -> jnp.ndarray:
    """Spacing of the FP8 lattice at |x| (f32)."""
    ax = jnp.abs(x)
    # Exponent of the binade containing |x|; clamp into [emin, emax-ish].
    # For subnormals the spacing is constant 2**(emin - man_bits).
    e = jnp.floor(jnp.log2(jnp.maximum(ax, 1e-45)))
    e = jnp.clip(e, fmt.emin, None)
    # exp2 is a polynomial approximation (inexact!); ldexp is bit-exact.
    return jnp.ldexp(jnp.float32(1.0), (e - fmt.man_bits).astype(jnp.int32))


def quantize(
    x: jnp.ndarray,
    fmt: Fp8Format,
    rounding: str = RTN,
    key: jax.Array | None = None,
) -> jnp.ndarray:
    """Round f32 values onto the FP8 lattice of ``fmt`` (saturating).

    RTN uses round-half-to-even (hardware default); SR implements the
    paper's Eq. 2: round up with probability (x - x_down)/(x_up - x_down).
    """
    x = x.astype(jnp.float32)
    q = _quantum(fmt, x)
    scaled = x / q
    if rounding == RTN:
        r = jnp.round(scaled)  # jnp.round is half-to-even
    elif rounding == SR:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        lo = jnp.floor(scaled)
        p_up = scaled - lo
        u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
        r = lo + (u < p_up).astype(jnp.float32)
    else:
        raise ValueError(f"unknown rounding mode {rounding!r}")
    y = r * q
    # Rounding up across a binade boundary lands exactly on the next
    # binade's smallest value, which is representable; only clamp range.
    y = jnp.clip(y, -fmt.max_finite, fmt.max_finite)
    # Preserve signed zeros / flush values below half the smallest
    # subnormal to zero (round() already does this for RTN).
    return jnp.where(jnp.isfinite(x), y, jnp.sign(x) * fmt.max_finite)


# ---------------------------------------------------------------------------
# Scaling strategies (paper §4.1: dynamic vs static; §3.2 power-of-2)
# ---------------------------------------------------------------------------

#: Gaudi-2 hardware-accelerated per-tensor exponent-bias scales (§3.2).
GAUDI2_HW_SCALES = (2.0**-8, 2.0**-4, 2.0**0, 2.0**4)


def amax_scale(x: jnp.ndarray, fmt: Fp8Format, axis=None) -> jnp.ndarray:
    """Dynamic amax scale: s such that x/s fills the format's range."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, 1e-12) / fmt.max_finite


def row_scales(x: jnp.ndarray, fmt: Fp8Format) -> jnp.ndarray:
    """Dynamic per-row (per-token) scales over the last axis."""
    return amax_scale(x, fmt, axis=-1)


def tensor_scale(x: jnp.ndarray, fmt: Fp8Format) -> jnp.ndarray:
    """Dynamic per-tensor scale."""
    return amax_scale(x, fmt, axis=None)


def pow2_scale(scale: jnp.ndarray, hw_set: tuple[float, ...] | None = None) -> jnp.ndarray:
    """Snap a scale up to a power of two (Gaudi exponent-bias trick).

    With ``hw_set`` given (Gaudi 2), snap to the smallest member of the
    fixed hardware set that is >= scale (falling back to the largest).
    """
    if hw_set is None:
        return jnp.ldexp(jnp.float32(1.0),
                         jnp.ceil(jnp.log2(scale)).astype(jnp.int32))
    s = jnp.asarray(sorted(hw_set), dtype=jnp.float32)
    idx = jnp.searchsorted(s, jnp.asarray(scale, jnp.float32))
    idx = jnp.clip(idx, 0, len(hw_set) - 1)
    return s[idx]


def quantize_scaled(
    x: jnp.ndarray,
    fmt: Fp8Format,
    scale: jnp.ndarray,
    rounding: str = RTN,
    key: jax.Array | None = None,
) -> jnp.ndarray:
    """Quantize x/scale onto the lattice; returns lattice values (f32).

    The caller keeps ``scale`` to dequantize GEMM outputs.
    ``scale`` broadcasts (per-tensor scalar or per-row column vector).
    """
    return quantize(x / scale, fmt, rounding, key)
