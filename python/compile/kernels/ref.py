"""Pure-jnp correctness oracles for the L1 kernels.

Deliberately *independent* implementations:

  * FP8 rounding is checked against an explicitly enumerated value
    lattice (every FP8 format has <= 256 values, so we can build the
    exact set from bit semantics and round by nearest-with-ties-to-even
    via searchsorted) — a totally different algorithm from the
    exponent-arithmetic path used by the kernels.
  * GEMM is plain numpy matmul in f64.
"""

from __future__ import annotations

import functools

import numpy as np

from . import fp8


@functools.lru_cache(maxsize=None)
def lattice(fmt_name: str) -> np.ndarray:
    """All non-negative finite values of the format, ascending (f64)."""
    fmt = fp8.FORMATS[fmt_name]
    vals = {0.0}
    # Subnormals: m * 2**(emin - man_bits), m in 1..2**man_bits - 1.
    for m in range(1, 2**fmt.man_bits):
        vals.add(m * 2.0 ** (fmt.emin - fmt.man_bits))
    # Normals: (1 + m/2**man_bits) * 2**e while <= max_finite.
    e = fmt.emin
    while 2.0**e <= fmt.max_finite:
        for m in range(2**fmt.man_bits):
            v = (1.0 + m / 2**fmt.man_bits) * 2.0**e
            if v <= fmt.max_finite:
                vals.add(v)
        e += 1
    arr = np.array(sorted(vals), dtype=np.float64)
    assert arr[-1] == fmt.max_finite, (fmt_name, arr[-1])
    return arr


def ref_quantize_rtn(x: np.ndarray, fmt: fp8.Fp8Format) -> np.ndarray:
    """Nearest-lattice-value rounding with ties-to-even, saturating."""
    lat = lattice(fmt.name)
    ax = np.abs(np.asarray(x, dtype=np.float64))
    idx = np.searchsorted(lat, ax)  # lat[idx-1] <= ax < lat[idx]
    idx = np.clip(idx, 1, len(lat) - 1)
    lo, hi = lat[idx - 1], lat[idx]
    mid = (lo + hi) / 2.0
    take_hi = ax > mid
    # Ties-to-even: the candidate whose mantissa code is even. Lattice
    # index parity tracks mantissa-code parity (index 0 is +0, code 0).
    tie = ax == mid
    hi_even = (idx % 2) == 0
    take_hi = take_hi | (tie & hi_even)
    y = np.where(take_hi, hi, lo)
    y = np.where(ax >= lat[-1], lat[-1], y)  # saturate
    return (np.sign(x) * y).astype(np.float32)


def ref_scaled_gemm(xq, wq, sx, sw):
    """f64 reference of the fused-dequant GEMM."""
    acc = np.asarray(xq, np.float64) @ np.asarray(wq, np.float64)
    return (acc * np.asarray(sx, np.float64) * np.asarray(sw, np.float64)).astype(
        np.float32
    )


def ref_fp8_matmul(x, w, fmt: fp8.Fp8Format, scaling: str = "per_row"):
    """End-to-end reference FP8 matmul (RTN only)."""
    x = np.asarray(x, np.float32)
    w = np.asarray(w, np.float32)
    sw = np.maximum(np.max(np.abs(w), axis=0, keepdims=True), 1e-12) / fmt.max_finite
    wq = ref_quantize_rtn(w / sw, fmt)
    if scaling == "per_row":
        sx = np.maximum(np.max(np.abs(x), axis=1, keepdims=True), 1e-12) / fmt.max_finite
    elif scaling == "per_tensor":
        sx = np.full((x.shape[0], 1), max(np.max(np.abs(x)), 1e-12) / fmt.max_finite,
                     np.float32)
    else:
        raise ValueError(scaling)
    xq = ref_quantize_rtn(x / sx, fmt)
    return ref_scaled_gemm(xq, wq, sx, sw)


def ref_decode_attention(q, k_cache, v_cache, lengths):
    """Reference GQA decode attention.

    q: (B, H, d); k_cache/v_cache: (B, S, Hkv, d); lengths: (B,) valid
    prefix lengths. Returns (B, H, d).
    """
    q = np.asarray(q, np.float32)
    k = np.asarray(k_cache, np.float32)
    v = np.asarray(v_cache, np.float32)
    b, h, d = q.shape
    _, s, hkv, _ = k.shape
    g = h // hkv
    out = np.zeros((b, h, d), np.float32)
    for bi in range(b):
        for hi in range(h):
            kv = hi // g
            scores = (k[bi, :, kv, :] @ q[bi, hi] / np.sqrt(d)).astype(np.float64)
            scores[lengths[bi]:] = -np.inf
            scores -= scores.max()
            p = np.exp(scores)
            p /= p.sum()
            out[bi, hi] = (p[:, None] * v[bi, :, kv, :]).sum(axis=0)
    return out


def ref_rmsnorm(x, w, eps=1e-5):
    x64 = np.asarray(x, np.float64)
    return (x64 / np.sqrt((x64**2).mean(-1, keepdims=True) + eps)
            * np.asarray(w, np.float64)).astype(np.float32)
