"""L1 Pallas kernel: GQA decode attention (one query token per sequence).

The decode-phase attention the paper analyzes in §5.2/§5.7: a GEMV (or
thin GEMM with GQA) per sequence against its KV cache, plus a softmax
whose exponential cost scales O(B*S) and — on Gaudi — lands on the TPC
vector cores rather than an SFU.

Grid is (B,): one program per sequence, blocks hold the sequence's full
cache (fits VMEM for the tiny serve-able models; for large S a second
grid axis with online-softmax would be the flash-decoding schedule).
Attention stays BF16/f32 — the paper keeps attention out of FP8 (§5.2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, groups: int):
    # q: (1, H, d) ; k/v: (1, S, Hkv, d) ; len: (1, 1) ; o: (1, H, d)
    q = q_ref[0].astype(jnp.float32)          # (H, d)
    k = k_ref[0].astype(jnp.float32)          # (S, Hkv, d)
    v = v_ref[0].astype(jnp.float32)
    n = len_ref[0, 0]
    h, d = q.shape
    s, hkv, _ = k.shape
    # Expand KV heads to query heads (GQA): head hi uses kv head hi//g.
    qh = q.reshape(hkv, groups, d)
    # scores[kv, g, s] = sum_d qh[kv, g, d] * k[s, kv, d]
    scores = jnp.einsum("kgd,skd->kgs", qh, k) / jnp.sqrt(jnp.float32(d))
    mask = jnp.arange(s)[None, None, :] < n
    # Large finite negative, NOT -inf: the AOT consumer (xla_extension
    # 0.5.1) turns exp(-inf - max) into NaN under fast-math; -1e30
    # underflows to 0 on every backend.
    scores = jnp.where(mask, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("kgs,skd->kgd", p, v)
    o_ref[0] = out.reshape(h, d)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     lengths: jnp.ndarray) -> jnp.ndarray:
    """GQA decode attention over dense KV caches.

    q: (B, H, d); k_cache/v_cache: (B, S, Hkv, d); lengths: (B,) int32.
    Returns (B, H, d) f32.
    """
    b, h, d = q.shape
    _, s, hkv, _ = k_cache.shape
    assert h % hkv == 0, (h, hkv)
    kern = functools.partial(_decode_attn_kernel, groups=h // hkv)
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, hkv, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, s, hkv, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        interpret=True,
    )(q.astype(jnp.float32), k_cache.astype(jnp.float32),
      v_cache.astype(jnp.float32), lengths.reshape(b, 1).astype(jnp.int32))
