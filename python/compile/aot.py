"""AOT pipeline: lower L2 model functions to HLO text for the rust L3.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Outputs (under artifacts/):
  model/<tier>/prefill_b{B}_s{S}.hlo.txt   — weights baked as constants
  model/<tier>/decode_b{B}.hlo.txt
  model/<tier>/meta.json                    — shapes the rust side needs
  gemm/fp8_gemm_{m}x{k}x{n}.hlo.txt         — standalone L1 kernel artifact
  golden/*.json                             — cross-language golden vectors

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import os
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .kernels import fp8, fp8_gemm, ref

# Serving artifact shape grid: one executable per (phase, batch) —
# the L3 batcher picks the smallest bucket that fits (vLLM-style
# bucketed shapes; fixed shapes are a PJRT AOT requirement).
PREFILL_SHAPES = [(1, 32), (2, 32), (4, 32), (8, 32)]   # (batch, seq)
DECODE_BATCHES = [1, 2, 4, 8]
SERVE_TIER = "1b"
SERVE_MAX_SEQ = 128


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)/1e6:.2f} MB)")


def export_serving_model(out_dir: str, tier: str, params) -> None:
    """Lower prefill/decode with weights closed over (baked constants)."""
    import dataclasses
    cfg = dataclasses.replace(M.TIERS[tier], max_seq=SERVE_MAX_SEQ)
    prec = M.FP8_DYNAMIC
    mdir = os.path.join(out_dir, "model", tier)

    for b, s in PREFILL_SHAPES:
        fn = lambda tok, lens: M.prefill(params, cfg, prec, tok, lens)
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((b, s), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        )
        _write(os.path.join(mdir, f"prefill_b{b}_s{s}.hlo.txt"),
               to_hlo_text(lowered))

    kv_shape = (cfg.layers, None, SERVE_MAX_SEQ, cfg.kv_heads, cfg.head_dim)
    for b in DECODE_BATCHES:
        fn = lambda tok, lens, kc, vc: M.decode_step(
            params, cfg, prec, tok, lens, kc, vc)
        shape = (cfg.layers, b, SERVE_MAX_SEQ, cfg.kv_heads, cfg.head_dim)
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct(shape, jnp.float32),
            jax.ShapeDtypeStruct(shape, jnp.float32),
        )
        _write(os.path.join(mdir, f"decode_b{b}.hlo.txt"),
               to_hlo_text(lowered))

    meta = {
        "tier": tier,
        "vocab": cfg.vocab,
        "hidden": cfg.hidden,
        "layers": cfg.layers,
        "heads": cfg.heads,
        "kv_heads": cfg.kv_heads,
        "head_dim": cfg.head_dim,
        "intermediate": cfg.intermediate,
        "max_seq": SERVE_MAX_SEQ,
        "prefill_shapes": PREFILL_SHAPES,
        "decode_batches": DECODE_BATCHES,
        "precision": "fp8_e4m3fn_dynamic_rowwise",
        "param_count": cfg.param_count(),
    }
    _write(os.path.join(mdir, "meta.json"), json.dumps(meta, indent=1))


def export_gemm_kernel(out_dir: str) -> None:
    """Standalone L1 FP8-GEMM artifact + golden I/O for the rust tests."""
    m, k, n = 128, 256, 128
    cfg = fp8_gemm.Fp8GemmConfig()
    fn = lambda x, w: (fp8_gemm.fp8_matmul(x, w, cfg),)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    )
    _write(os.path.join(out_dir, "gemm", f"fp8_gemm_{m}x{k}x{n}.hlo.txt"),
           to_hlo_text(lowered))

    rng = np.random.default_rng(1234)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    y = np.asarray(fp8_gemm.fp8_matmul(jnp.asarray(x), jnp.asarray(w), cfg))
    golden = {
        "m": m, "k": k, "n": n,
        "x": x.flatten().tolist(),
        "w": w.flatten().tolist(),
        "y": y.flatten().tolist(),
    }
    _write(os.path.join(out_dir, "golden", "fp8_gemm_io.json"),
           json.dumps(golden))


def export_quantize_golden(out_dir: str) -> None:
    """Golden FP8 quantization vectors: python emulation -> rust fp8.

    The rust `fp8` module must agree bit-exactly on every value.
    """
    rng = np.random.default_rng(99)
    xs = np.concatenate([
        rng.standard_normal(512) * rng.choice([0.01, 1.0, 64.0, 500.0], 512),
        np.array([0.0, 448.0, -448.0, 240.0, 240.1, 457.0, -1e-9, 1e9,
                  2.0**-9, 2.0**-10, 0.875 * 2.0**-6, 57344.0, -60000.0]),
    ]).astype(np.float32)
    out = {"x": xs.tolist()}
    for fmt in (fp8.E4M3FN, fp8.E4M3_GAUDI, fp8.E5M2):
        q = np.asarray(fp8.quantize(jnp.asarray(xs), fmt, fp8.RTN))
        out[fmt.name] = q.tolist()
    _write(os.path.join(out_dir, "golden", "fp8_quantize.json"),
           json.dumps(out))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--tier", default=SERVE_TIER)
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--skip-train", action="store_true",
                    help="use random weights (CI fast path)")
    args = ap.parse_args()

    out = args.out
    os.makedirs(out, exist_ok=True)

    ckpt = os.path.join(out, "ckpt", f"{args.tier}.npz")
    if args.skip_train:
        params = M.init_params(M.TIERS[args.tier], jax.random.PRNGKey(0))
    elif os.path.exists(ckpt):
        print(f"reusing checkpoint {ckpt}")
        params = T.load_params(ckpt)
    else:
        print(f"training serve tier '{args.tier}' ({args.train_steps} steps)")
        params, cfg, _ = T.train_tier(args.tier, args.train_steps)
        os.makedirs(os.path.dirname(ckpt), exist_ok=True)
        T.save_params(params, ckpt)

    print("exporting serving model artifacts")
    export_serving_model(out, args.tier, params)
    print("exporting standalone GEMM kernel artifact")
    export_gemm_kernel(out)
    print("exporting golden quantization vectors")
    export_quantize_golden(out)
    # Sentinel for `make` freshness checking.
    with open(os.path.join(out, ".stamp"), "w") as f:
        f.write("ok\n")
    print("artifacts complete")


if __name__ == "__main__":
    main()
