"""L1 §Perf analysis: VMEM footprint + MXU-utilization estimates per
BlockSpec (interpret=True gives no TPU timing, so kernel quality is
assessed structurally — DESIGN.md §6).

For the fused-dequant FP8 GEMM kernel (fp8_gemm.scaled_gemm):
  resident per grid step = x tile (bm x bk) + w tile (bk x bn)
                         + output/accumulator tile (bm x bn, f32)
                         + scale slivers (bm x 1, 1 x bn)
MXU utilization estimate = fraction of 128x128-systolic issue slots
doing useful MACs given tile alignment (the TPU analogue of the
paper's Gaudi MME folding analysis, Fig. 8).

Usage: python -m compile.vmem  -> prints the table for the shipped
kernel configurations and asserts the VMEM budget.
"""

from __future__ import annotations

import dataclasses
import math

from .kernels.fp8_gemm import Fp8GemmConfig

#: v4/v5-class core VMEM budget (bytes) — we keep a safety margin.
VMEM_BUDGET = 16 * 1024 * 1024
MXU = 128  # systolic array edge


@dataclasses.dataclass(frozen=True)
class KernelEstimate:
    bm: int
    bn: int
    bk: int
    vmem_bytes: int
    mxu_utilization: float
    k_steps_per_output: float

    @property
    def fits(self) -> bool:
        return self.vmem_bytes <= VMEM_BUDGET


def estimate(cfg: Fp8GemmConfig, m: int, k: int, n: int,
             in_bytes: int = 4, acc_bytes: int = 4) -> KernelEstimate:
    """Footprint/utilization estimate for scaled_gemm's BlockSpecs.

    ``in_bytes`` is 4 under interpret emulation (lattice values in
    f32); a real-TPU FP8 kernel would store 1-byte operands, so both
    views are reported by callers where relevant.
    """
    bm, bn, bk = min(cfg.bm, m), min(cfg.bn, n), min(cfg.bk, k)
    vmem = (
        bm * bk * in_bytes        # x tile
        + bk * bn * in_bytes      # w tile
        + bm * bn * acc_bytes     # output/accumulator tile
        + bm * 1 * 4 + 1 * bn * 4  # scale slivers
    )
    # Double-buffered input tiles (pallas pipelines the HBM->VMEM copy).
    vmem += (bm * bk + bk * bn) * in_bytes

    # MXU issue-slot utilization from tile alignment to the 128x128
    # array: ceil waste in each dim.
    def frac(d):
        return d / (math.ceil(d / MXU) * MXU)

    util = frac(bm) * frac(bn) * frac(bk)
    return KernelEstimate(
        bm=bm, bn=bn, bk=bk,
        vmem_bytes=vmem,
        mxu_utilization=util,
        k_steps_per_output=math.ceil(k / bk),
    )


def report(shapes=((64, 4096, 4096), (128, 4096, 14336),
                   (2048, 4096, 4096), (8, 1024, 1024))):
    cfg = Fp8GemmConfig()
    rows = []
    for m, k, n in shapes:
        e = estimate(cfg, m, k, n)
        rows.append((m, k, n, e))
    return rows


def main():
    print(f"{'shape':>20} {'tiles':>14} {'VMEM KiB':>9} {'MXU util':>9} fits")
    for m, k, n, e in report():
        print(f"{f'({m},{k},{n})':>20} {f'{e.bm}x{e.bn}x{e.bk}':>14} "
              f"{e.vmem_bytes / 1024:>9.0f} {e.mxu_utilization:>9.2f} "
              f"{e.fits}")
        assert e.fits, "kernel tile set exceeds VMEM budget"


if __name__ == "__main__":
    main()
