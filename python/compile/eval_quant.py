"""Tables 4-5 harness: FP8 quantization-config accuracy on the tiers.

Substitutes for the paper's MMLU/GSM8K/Winogrande/TruthfulQA suite
(DESIGN.md): five synthetic-language tasks whose mechanics mirror the
paper's — multiple-choice by sequence log-likelihood, and next-token
metrics.  What must transfer is the *ordering* across quantization
configs (dynamic >= static, E4M3 > E5M2 shrinking with size, SR ~ RTN),
which is driven by quantization-error statistics, not task content.

Outputs artifacts/results/table4.json and table5.json.

Usage: python -m compile.eval_quant --out ../artifacts [--tiers 1b,3b]
"""

from __future__ import annotations

import argparse
import json
import os
import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from . import model as M
from . import train as T
from .kernels import fp8, fp8_gemm

SEQ = 64
N_MCQ = 32          # multiple-choice items per task
N_PPL = 24          # held-out sequences for token metrics
N_CHOICES = 4
PREFIX = 32


def build_eval_sets(lang: T.SyntheticLanguage, seed: int = 7777):
    """Deterministic eval data, disjoint from training by seed."""
    rng = np.random.default_rng(seed)
    ppl_set = lang.batch(rng, N_PPL, SEQ)

    # MCQ-hard: true continuation vs 3 resampled continuations from the
    # same language (plausible distractors — the MMLU analogue).
    # MCQ-easy: distractors are uniform-random tokens.
    mcq_hard, mcq_easy = [], []
    for _ in range(N_MCQ):
        seqs = lang.batch(rng, 1, SEQ)
        true = seqs[0]
        hard = [true]
        for _ in range(N_CHOICES - 1):
            alt = true.copy()
            alt[PREFIX:] = lang.sample(rng, SEQ)[PREFIX:]
            hard.append(alt)
        easy = [true]
        for _ in range(N_CHOICES - 1):
            alt = true.copy()
            alt[PREFIX:] = rng.integers(0, T.VOCAB, SEQ - PREFIX)
            easy.append(alt)
        mcq_hard.append(np.stack(hard))
        mcq_easy.append(np.stack(easy))
    return ppl_set, np.stack(mcq_hard), np.stack(mcq_easy)


def eval_config(params, cfg, prec, ppl_set, mcq_hard, mcq_easy):
    """Run the 5 tasks; returns a dict of metrics (percent)."""
    seqlp = jax.jit(partial(M.sequence_logprob, params, cfg, prec,
                            prefix_len=PREFIX))

    def mcq_acc(items):
        correct = 0
        for item in items:                       # (C, S)
            lps = np.asarray(seqlp(tokens=jnp.asarray(item)))
            correct += int(np.argmax(lps) == 0)
        return 100.0 * correct / len(items)

    # Token-level metrics on held-out text.
    b, s = ppl_set.shape
    lengths = jnp.full((b,), s, jnp.int32)
    logits, _, _ = jax.jit(partial(M.prefill, params, cfg, prec))(
        tokens=jnp.asarray(ppl_set), lengths=lengths)
    logp = jax.nn.log_softmax(np.asarray(logits[:, :-1], np.float32), axis=-1)
    tgt = ppl_set[:, 1:]
    tok_lp = np.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    top1 = (np.argmax(logp, -1) == tgt).mean() * 100.0
    top5 = (np.argsort(logp, -1)[..., -5:] == tgt[..., None]).any(-1).mean() * 100.0
    ppl = float(np.exp(-tok_lp.mean()))

    return {
        "mcq_hard": mcq_acc(mcq_hard),          # MMLU-analogue
        "mcq_easy": mcq_acc(mcq_easy),          # Winogrande-analogue
        "next_tok_top1": float(top1),           # GSM8K-analogue
        "next_tok_top5": float(top5),           # TruthfulQA-mc1-analogue
        "ppl": ppl,                             # TruthfulQA-mc2-analogue
    }


def precision_grid(params, cfg, calib_tokens):
    """The configs of Tables 4 & 5."""
    static_scales = M.calibrate_static_scales(
        params, cfg, calib_tokens, fp8.E4M3FN)
    return {
        "bf16": M.BF16,
        "fp8_dynamic": M.PrecisionConfig(
            mode="fp8", fmt=fp8.E4M3FN, scaling=fp8_gemm.PER_ROW),
        "fp8_static": M.PrecisionConfig(
            mode="fp8", fmt=fp8.E4M3FN, scaling=fp8_gemm.STATIC,
            static_scales=static_scales),
        "e4m3_rtn": M.PrecisionConfig(
            mode="fp8", fmt=fp8.E4M3_GAUDI, scaling=fp8_gemm.PER_ROW),
        "e4m3_sr": M.PrecisionConfig(
            mode="fp8", fmt=fp8.E4M3_GAUDI, rounding=fp8.SR,
            scaling=fp8_gemm.PER_ROW),
        "e5m2_rtn": M.PrecisionConfig(
            mode="fp8", fmt=fp8.E5M2, scaling=fp8_gemm.PER_ROW),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--tiers", default="1b,3b,8b,70b")
    ap.add_argument("--train-steps", type=int, default=400)
    args = ap.parse_args()
    tiers = args.tiers.split(",")

    lang = T.SyntheticLanguage(seed=0)
    ppl_set, mcq_hard, mcq_easy = build_eval_sets(lang)
    calib = jnp.asarray(lang.batch(np.random.default_rng(555), 8, SEQ))

    os.makedirs(os.path.join(args.out, "results"), exist_ok=True)
    table4, table5 = {}, {}
    for tier in tiers:
        ckpt = os.path.join(args.out, "ckpt", f"{tier}.npz")
        cfg = M.TIERS[tier]
        if os.path.exists(ckpt):
            params = T.load_params(ckpt)
            print(f"[{tier}] loaded {ckpt}")
        else:
            print(f"[{tier}] training ({args.train_steps} steps)")
            params, cfg, _ = T.train_tier(tier, args.train_steps)
            os.makedirs(os.path.dirname(ckpt), exist_ok=True)
            T.save_params(params, ckpt)

        grid = precision_grid(params, cfg, calib)
        results = {}
        for name, prec in grid.items():
            t0 = time.time()
            results[name] = eval_config(params, cfg, prec,
                                        ppl_set, mcq_hard, mcq_easy)
            print(f"[{tier}] {name:12s} "
                  f"mcq_hard={results[name]['mcq_hard']:5.1f} "
                  f"top1={results[name]['next_tok_top1']:5.1f} "
                  f"ppl={results[name]['ppl']:6.2f} "
                  f"({time.time()-t0:.0f}s)")

        # Table 4 (paper: 8B tier only): BF16 vs static vs dynamic.
        if tier == "8b":
            table4 = {k: results[k] for k in ("bf16", "fp8_static",
                                              "fp8_dynamic")}
        # Table 5: per-tier BF16 / E4M3-SR / E4M3-RTN / E5M2-RTN on the
        # MMLU-analogue (mcq_hard).
        table5[tier] = {
            "params": cfg.param_count(),
            "bf16": results["bf16"]["mcq_hard"],
            "e4m3_sr": results["e4m3_sr"]["mcq_hard"],
            "e4m3_rtn": results["e4m3_rtn"]["mcq_hard"],
            "e5m2_rtn": results["e5m2_rtn"]["mcq_hard"],
            "full": results,
        }

    with open(os.path.join(args.out, "results", "table4.json"), "w") as f:
        json.dump(table4, f, indent=1)
    with open(os.path.join(args.out, "results", "table5.json"), "w") as f:
        json.dump(table5, f, indent=1)
    print("wrote table4.json, table5.json")


if __name__ == "__main__":
    main()
