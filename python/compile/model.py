"""L2: Llama-architecture forward pass in JAX, calling the L1 kernels.

Mirrors the model family the paper evaluates (Llama v3.x, §4-5): RMSNorm,
rotary embeddings, grouped-query attention, SwiGLU MLP. Precision
accounting follows the paper's §5.2 split exactly:

  * all block linears (QKV/O, gate/up/down)  -> FP8 (configurable)
  * attention (QK^T, softmax, PV)            -> BF16/f32
  * LM head + embeddings                     -> BF16

Two entry points, both AOT-lowerable at fixed shapes:
  * ``prefill``      — process a full (B, S) prompt, build KV caches.
  * ``decode_step``  — one autoregressive step over a (B,) token batch,
                        using the L1 Pallas decode-attention kernel.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import fp8, fp8_gemm

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Llama-style architecture hyperparameters.

    The four tiers mirror the relative widths of Llama v3.2 1B / 3B /
    v3.1 8B / v3.3 70B at toy scale (DESIGN.md substitution table).
    """

    vocab: int = 256
    hidden: int = 64
    layers: int = 2
    heads: int = 4
    kv_heads: int = 2
    intermediate: int = 172      # ~2.7x hidden, SwiGLU
    max_seq: int = 128
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    @property
    def gqa_groups(self) -> int:
        assert self.heads % self.kv_heads == 0
        return self.heads // self.kv_heads

    def param_count(self) -> int:
        h, a, v, l = self.hidden, self.intermediate, self.vocab, self.layers
        kv = self.kv_heads * self.head_dim
        per_layer = h * h + 2 * h * kv + h * h + 3 * h * a + 2 * h
        return l * per_layer + 2 * v * h + h


# Paper-tier analogues (§4 Tables 4-5): widths scale like 1B/3B/8B/70B.
TIERS = {
    "1b": ModelConfig(hidden=64, layers=2, heads=4, kv_heads=2, intermediate=172),
    "3b": ModelConfig(hidden=96, layers=3, heads=6, kv_heads=2, intermediate=256),
    "8b": ModelConfig(hidden=128, layers=4, heads=8, kv_heads=2, intermediate=344),
    "70b": ModelConfig(hidden=256, layers=6, heads=8, kv_heads=2, intermediate=688),
}


@dataclasses.dataclass(frozen=True)
class PrecisionConfig:
    """How the block linears are computed (LM head is always BF16)."""

    mode: str = "bf16"                  # "bf16" | "fp8"
    fmt: fp8.Fp8Format = fp8.E4M3FN
    rounding: str = fp8.RTN
    scaling: str = fp8_gemm.PER_ROW     # per_row|per_tensor|static|pow2
    # static per-tensor activation scales keyed by layer name, from
    # calibration (``calibrate_static_scales``).
    static_scales: dict[str, float] | None = None

    def gemm_cfg(self) -> fp8_gemm.Fp8GemmConfig:
        return fp8_gemm.Fp8GemmConfig(
            fmt=self.fmt, rounding=self.rounding, scaling=self.scaling)


BF16 = PrecisionConfig()
FP8_DYNAMIC = PrecisionConfig(mode="fp8", scaling=fp8_gemm.PER_ROW)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Gaussian init scaled like Llama (std 0.02, out-proj depth-scaled)."""
    keys = iter(jax.random.split(key, 4 + cfg.layers * 7))

    def mat(shape, std=0.02):
        return (jax.random.normal(next(keys), shape, jnp.float32) * std)

    kvdim = cfg.kv_heads * cfg.head_dim
    params: Params = {
        "embed": mat((cfg.vocab, cfg.hidden)),
        "lm_head": mat((cfg.hidden, cfg.vocab)),
        "final_norm": jnp.ones((cfg.hidden,), jnp.float32),
        "layers": [],
    }
    out_std = 0.02 / jnp.sqrt(2.0 * cfg.layers)
    for _ in range(cfg.layers):
        params["layers"].append({
            "attn_norm": jnp.ones((cfg.hidden,), jnp.float32),
            "wq": mat((cfg.hidden, cfg.hidden)),
            "wk": mat((cfg.hidden, kvdim)),
            "wv": mat((cfg.hidden, kvdim)),
            "wo": mat((cfg.hidden, cfg.hidden), out_std),
            "mlp_norm": jnp.ones((cfg.hidden,), jnp.float32),
            "w_gate": mat((cfg.hidden, cfg.intermediate)),
            "w_up": mat((cfg.hidden, cfg.intermediate)),
            "w_down": mat((cfg.intermediate, cfg.hidden), out_std),
        })
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def rope_freqs(cfg: ModelConfig, positions: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for the given positions; shape (..., head_dim/2)."""
    d = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., heads, head_dim); cos/sin broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def linear(x: jnp.ndarray, w: jnp.ndarray, prec: PrecisionConfig,
           name: str = "") -> jnp.ndarray:
    """A block linear: FP8 via the L1 Pallas kernel, or BF16 fallback.

    x: (..., K) is flattened to (M, K) for the kernel.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if prec.mode == "bf16":
        y = jnp.dot(x2.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32)
    else:
        x_scale = None
        cfg = prec.gemm_cfg()
        if prec.scaling == fp8_gemm.STATIC:
            scales = prec.static_scales or {}
            x_scale = scales.get(name, 1.0 / prec.fmt.max_finite)
        y = fp8_gemm.fp8_matmul(x2, w, cfg, x_scale=x_scale)
    return y.reshape(*lead, w.shape[-1]).astype(jnp.float32)


def _attention_prefill(q, k, v, lengths, cfg: ModelConfig):
    """Causal GQA attention over full sequences (compute-bound phase).

    q: (B, S, H, d); k/v: (B, S, Hkv, d). BF16-class math, f32 softmax.
    """
    b, s, h, d = q.shape
    g = cfg.gqa_groups
    kq = jnp.repeat(k, g, axis=2)  # (B, S, H, d)
    vq = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kq) / jnp.sqrt(jnp.float32(d))
    pos = jnp.arange(s)
    causal = pos[None, :] <= pos[:, None]                  # (q, k)
    valid = pos[None, :] < lengths[:, None]                # (b, k)
    mask = causal[None, None, :, :] & valid[:, None, None, :]
    # Large finite negative, NOT -inf: xla_extension 0.5.1 (the AOT
    # consumer) compiles exp(-inf - max) to NaN under its fast-math
    # defaults; -1e30 underflows to 0 portably.
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vq)


def _block_prefill(x, layer, lengths, cos, sin, cfg, prec, li):
    b, s, h = x.shape
    d, hq, hkv = cfg.head_dim, cfg.heads, cfg.kv_heads
    xn = rmsnorm(x, layer["attn_norm"], cfg.norm_eps)
    q = linear(xn, layer["wq"], prec, f"l{li}.wq").reshape(b, s, hq, d)
    k = linear(xn, layer["wk"], prec, f"l{li}.wk").reshape(b, s, hkv, d)
    v = linear(xn, layer["wv"], prec, f"l{li}.wv").reshape(b, s, hkv, d)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = _attention_prefill(q, k, v, lengths, cfg).reshape(b, s, hq * d)
    x = x + linear(o, layer["wo"], prec, f"l{li}.wo")
    xn = rmsnorm(x, layer["mlp_norm"], cfg.norm_eps)
    gate = linear(xn, layer["w_gate"], prec, f"l{li}.w_gate")
    up = linear(xn, layer["w_up"], prec, f"l{li}.w_up")
    x = x + linear(jax.nn.silu(gate) * up, layer["w_down"], prec,
                   f"l{li}.w_down")
    return x, k, v


def prefill(params: Params, cfg: ModelConfig, prec: PrecisionConfig,
            tokens: jnp.ndarray, lengths: jnp.ndarray):
    """Process (B, S) prompts; return logits and freshly built KV caches.

    Returns:
      logits  (B, S, vocab) f32
      k_cache (L, B, max_seq, Hkv, d) f32 — first S positions filled
      v_cache same shape.
    """
    b, s = tokens.shape
    x = params["embed"][tokens]                          # (B, S, h)
    positions = jnp.arange(s)[None, :].repeat(b, axis=0)
    cos, sin = rope_freqs(cfg, positions)

    kcs, vcs = [], []
    for li, layer in enumerate(params["layers"]):
        x, k, v = _block_prefill(x, layer, lengths, cos, sin, cfg, prec, li)
        kcs.append(k)
        vcs.append(v)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x.astype(jnp.bfloat16),
                     params["lm_head"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)

    pad = cfg.max_seq - s
    k_cache = jnp.stack([jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                         for k in kcs])
    v_cache = jnp.stack([jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                         for v in vcs])
    return logits, k_cache, v_cache


def decode_step(params: Params, cfg: ModelConfig, prec: PrecisionConfig,
                tokens: jnp.ndarray, lengths: jnp.ndarray,
                k_cache: jnp.ndarray, v_cache: jnp.ndarray):
    """One autoregressive step (the paper's memory-bound phase, §5.4).

    tokens: (B,) next input token per sequence.
    lengths: (B,) current cache fill (the new KV lands at this index).
    caches: (L, B, max_seq, Hkv, d).

    Returns (logits (B, vocab), k_cache', v_cache').
    """
    b = tokens.shape[0]
    d, hq, hkv = cfg.head_dim, cfg.heads, cfg.kv_heads
    x = params["embed"][tokens]                          # (B, h)
    cos, sin = rope_freqs(cfg, lengths)                  # (B, d/2)

    new_k, new_v = [], []
    for li, layer in enumerate(params["layers"]):
        xn = rmsnorm(x, layer["attn_norm"], cfg.norm_eps)
        q = linear(xn, layer["wq"], prec, f"l{li}.wq").reshape(b, hq, d)
        k = linear(xn, layer["wk"], prec, f"l{li}.wk").reshape(b, hkv, d)
        v = linear(xn, layer["wv"], prec, f"l{li}.wv").reshape(b, hkv, d)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        # Write the new KV at position `lengths` (per sequence).
        kc = _scatter_kv(k_cache[li], k, lengths)
        vc = _scatter_kv(v_cache[li], v, lengths)
        new_k.append(kc)
        new_v.append(vc)
        # L1 Pallas GQA decode-attention over the cache.
        o = attn_kernel.decode_attention(q, kc, vc, lengths + 1)
        x = x + linear(o.reshape(b, hq * d), layer["wo"], prec, f"l{li}.wo")
        xn = rmsnorm(x, layer["mlp_norm"], cfg.norm_eps)
        gate = linear(xn, layer["w_gate"], prec, f"l{li}.w_gate")
        up = linear(xn, layer["w_up"], prec, f"l{li}.w_up")
        x = x + linear(jax.nn.silu(gate) * up, layer["w_down"], prec,
                       f"l{li}.w_down")

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x.astype(jnp.bfloat16),
                     params["lm_head"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def _scatter_kv(cache: jnp.ndarray, new: jnp.ndarray,
                lengths: jnp.ndarray) -> jnp.ndarray:
    """cache: (B, S, Hkv, d); new: (B, Hkv, d); write at per-seq index."""
    b, s, hkv, d = cache.shape
    onehot = jax.nn.one_hot(lengths, s, dtype=cache.dtype)  # (B, S)
    return cache * (1.0 - onehot[..., None, None]) + (
        onehot[..., None, None] * new[:, None, :, :])


# ---------------------------------------------------------------------------
# Loss / sampling helpers (used by train.py and the eval harness)
# ---------------------------------------------------------------------------


def lm_loss(params: Params, cfg: ModelConfig, prec: PrecisionConfig,
            tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross entropy over (B, S) sequences (full length)."""
    b, s = tokens.shape
    lengths = jnp.full((b,), s, jnp.int32)
    logits, _, _ = prefill(params, cfg, prec, tokens, lengths)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


def sequence_logprob(params: Params, cfg: ModelConfig, prec: PrecisionConfig,
                     tokens: jnp.ndarray, prefix_len: int) -> jnp.ndarray:
    """Sum log p(tokens[prefix:] | tokens[:prefix]) per sequence (B,)."""
    b, s = tokens.shape
    lengths = jnp.full((b,), s, jnp.int32)
    logits, _, _ = prefill(params, cfg, prec, tokens, lengths)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    tok_lp = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    mask = jnp.arange(s - 1)[None, :] >= (prefix_len - 1)
    return (tok_lp * mask).sum(axis=-1)


def calibrate_static_scales(params: Params, cfg: ModelConfig,
                            calib_tokens: jnp.ndarray,
                            fmt: fp8.Fp8Format) -> dict[str, float]:
    """Per-tensor static activation scales from a calibration batch.

    Runs a BF16 forward pass capturing per-linear input amax (the INC-
    style calibration the paper's Table 4 'Cited' column uses).
    """
    amaxes: dict[str, float] = {}

    class Capture(PrecisionConfig):
        pass

    # Re-run prefill with a tracing precision that records amax via
    # host callbacks is overkill at build time — instead replay the
    # forward manually, mirroring `prefill`'s structure.
    b, s = calib_tokens.shape
    lengths = jnp.full((b,), s, jnp.int32)
    x = params["embed"][calib_tokens]
    positions = jnp.arange(s)[None, :].repeat(b, axis=0)
    cos, sin = rope_freqs(cfg, positions)
    d, hq, hkv = cfg.head_dim, cfg.heads, cfg.kv_heads

    def rec(name, t):
        amaxes[name] = float(jnp.max(jnp.abs(t)))

    for li, layer in enumerate(params["layers"]):
        xn = rmsnorm(x, layer["attn_norm"], cfg.norm_eps)
        for nm in ("wq", "wk", "wv"):
            rec(f"l{li}.{nm}", xn)
        q = (xn @ layer["wq"]).reshape(b, s, hq, d)
        k = (xn @ layer["wk"]).reshape(b, s, hkv, d)
        v = (xn @ layer["wv"]).reshape(b, s, hkv, d)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        o = _attention_prefill(q, k, v, lengths, cfg).reshape(b, s, hq * d)
        rec(f"l{li}.wo", o)
        x = x + o @ layer["wo"]
        xn = rmsnorm(x, layer["mlp_norm"], cfg.norm_eps)
        rec(f"l{li}.w_gate", xn)
        rec(f"l{li}.w_up", xn)
        gate = xn @ layer["w_gate"]
        up = xn @ layer["w_up"]
        h = jax.nn.silu(gate) * up
        rec(f"l{li}.w_down", h)
        x = x + h @ layer["w_down"]

    return {k: max(v, 1e-12) / fmt.max_finite for k, v in amaxes.items()}
